"""Monitor — the strategic loop's data-collection half (§3.1).

Collects metadata from completed requests into (a) a large historical window
for offline Refine-and-Prune runs and (b) a compact real-time window for
online adjustments, and computes the reward terms the Bayesian
meta-optimizer consumes (Eq. 5):

    R(Θ) = λ1·C + λ2·L − λ3·S − λ4·U

    C  queue compactness   — mean within-queue length homogeneity
    L  load balance        — negative imbalance across queues (higher=better)
    S  queue proliferation — number of active queues (penalty)
    U  user experience     — latency penalties (mean TTFT of short requests,
                             p95 e2e latency)
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from .types import Request


@dataclass
class RewardWeights:
    lam_compact: float = 1.0
    lam_balance: float = 0.5
    lam_spread: float = 0.05
    lam_ux: float = 2.0


@dataclass
class WindowStats:
    n: int
    mean_ttft_short: float
    mean_ttft: float
    p95_latency: float
    throughput_tokens: float
    throughput_reqs: float


class Monitor:
    def __init__(self, history_cap: int = 200_000, window_cap: int = 4096,
                 short_threshold: float = 256.0):
        self.history: deque[float] = deque(maxlen=history_cap)   # prompt lengths
        self.window: deque[Request] = deque(maxlen=window_cap)   # recent finished
        self.short_threshold = short_threshold
        self.total_finished = 0
        self.total_tokens_out = 0
        # True arrival count (the history deque is capped): the fleet policy
        # store weighs each replica's pooled sample by this.
        self.total_arrivals = 0

    # ---- ingestion ------------------------------------------------------

    def observe_arrival(self, req: Request) -> None:
        # The strategic loop partitions on *work* lengths (KV + prediction
        # planes): queue boundaries should separate requests by the work
        # they cost — uncached prefill plus predicted decode — not the
        # tokens they carry.  Equal to prompt_len when neither plane has
        # stamped the request.
        self.history.append(req.work_len)
        self.total_arrivals += 1

    def observe_finish(self, req: Request) -> None:
        self.window.append(req)
        self.total_finished += 1
        self.total_tokens_out += req.generated

    # ---- strategic-loop reads --------------------------------------------

    def historical_lengths(self) -> np.ndarray:
        return np.asarray(self.history, dtype=np.float64)

    def recent_lengths(self, n: int = 1024) -> np.ndarray:
        reqs = list(self.window)[-n:]
        return np.asarray([r.work_len for r in reqs], dtype=np.float64)

    def window_stats(self, wall_elapsed: float) -> WindowStats:
        reqs = list(self.window)
        if not reqs:
            return WindowStats(0, 0.0, 0.0, 0.0, 0.0, 0.0)
        ttfts = np.asarray([r.ttft for r in reqs if r.ttft is not None])
        short_ttfts = np.asarray([r.ttft for r in reqs
                                  if r.ttft is not None
                                  and r.prompt_len <= self.short_threshold])
        lats = np.asarray([r.e2e_latency for r in reqs
                           if r.e2e_latency is not None])
        tokens = sum(r.generated for r in reqs)
        dt = max(wall_elapsed, 1e-9)
        return WindowStats(
            n=len(reqs),
            mean_ttft_short=float(short_ttfts.mean()) if len(short_ttfts) else 0.0,
            mean_ttft=float(ttfts.mean()) if len(ttfts) else 0.0,
            p95_latency=float(np.percentile(lats, 95)) if len(lats) else 0.0,
            throughput_tokens=tokens / dt,
            throughput_reqs=len(reqs) / dt,
        )


def reward_terms(queue_lengths: list[np.ndarray], stats: WindowStats,
                 n_queues: int) -> dict[str, float]:
    """Compute the four Eq. 5 terms from the observable state.

    ``queue_lengths`` — per-queue arrays of routed prompt lengths."""
    occupied = [q for q in queue_lengths if len(q) > 1]
    # C: compactness — 1/(1+mean within-queue coefficient of variation).
    if occupied:
        cvs = [float(np.std(q) / (np.mean(q) + 1e-9)) for q in occupied]
        compact = 1.0 / (1.0 + float(np.mean(cvs)))
    else:
        compact = 0.0
    # L: load balance — 1/(1+CV of queue populations).
    pops = np.asarray([len(q) for q in queue_lengths], dtype=np.float64)
    if pops.sum() > 0:
        balance = 1.0 / (1.0 + float(pops.std() / (pops.mean() + 1e-9)))
    else:
        balance = 0.0
    # S: proliferation penalty — normalized queue count.
    spread = float(n_queues)
    # U: user-experience penalty — short-request TTFT plus tail latency.
    ux = stats.mean_ttft_short + 0.1 * stats.p95_latency
    return {"compact": compact, "balance": balance, "spread": spread, "ux": ux}


def reward(terms: dict[str, float], w: RewardWeights,
           throughput_bonus: float = 0.0) -> float:
    """Eq. 5, plus an optional throughput bonus used when the optimizer is
    driven by the live engine (throughput is part of 'user experience' in
    the paper's deployment; keeping it explicit makes ablations cleaner)."""
    return (w.lam_compact * terms["compact"]
            + w.lam_balance * terms["balance"]
            - w.lam_spread * terms["spread"]
            - w.lam_ux * terms["ux"]
            + throughput_bonus)
