"""Core datatypes shared by the EWSJF scheduler stack.

The scheduler is a host-side control layer (as in the paper, where it sits
above vLLM's execution engine), so these are plain Python dataclasses, not
pytrees.  The jit'd engine below consumes the batches this layer emits.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Optional

_REQUEST_COUNTER = itertools.count()


class RequestState(Enum):
    WAITING = "waiting"        # in a scheduler queue, not yet admitted
    RUNNING_PREFILL = "prefill"
    RUNNING_DECODE = "decode"
    PREEMPTED = "preempted"    # evicted (KV pressure); will be re-enqueued
    FINISHED = "finished"
    FAILED = "failed"


class TerminalState(Enum):
    """How a request's life ended — the *one* classification every plane
    agrees on.  Stamped exactly once (``Request.terminal``) at the point a
    request leaves the system, recorded by the tracer and counted in the
    metrics registry (``requests_terminal_total{state,slo_class}``), so
    the per-component shed/dropped counters can no longer diverge."""

    FINISHED = "finished"              # generated all tokens
    SHED = "shed"                      # rejected by admission / load shedding
    DEADLINE_DROPPED = "deadline_dropped"  # admitted, but missed its deadline


@dataclass
class Request:
    """One inference request as seen by the admission scheduler.

    ``prompt_len`` is the *input-side* signal EWSJF schedules on (the paper
    deliberately avoids output-length predictors, §2.3).
    """

    prompt_len: int
    arrival_time: float = 0.0
    max_new_tokens: int = 128
    request_id: int = field(default_factory=lambda: next(_REQUEST_COUNTER))
    prompt_tokens: Optional[Any] = None     # int array when actually executing
    priority_class: int = 0                 # optional operator hint (unused by EWSJF)

    # KV plane (prefix reuse).  ``prompt_hashes`` is the chained token-block
    # hash chain of the prompt (kvplane.radix) — None means no reuse is
    # possible.  ``cached_len`` is the router's estimate of prefix tokens
    # already resident on the assigned replica; the scheduler stack scores
    # and queues on the *effective* length (the uncached suffix), since
    # that is the work the request actually costs.  ``prefix_fetch`` is a
    # planned remote-prefix transfer (kvplane topology), set by a
    # prefix-aware router and consumed at dispatch.
    prompt_hashes: Optional[tuple] = None
    cached_len: int = 0
    prefix_fetch: Optional[Any] = None

    # Prediction plane (predicted-length scheduling).  ``predicted_output``
    # is a predictor's expected output-token count for this request;
    # ``predicted_extra`` is that estimate converted to *prefill-equivalent*
    # tokens (batch-amortized decode seconds / per-token prefill seconds),
    # kept additive so it composes with the KV plane's ``cached_len``
    # discount, which is stamped later by the router.  Both stay None when
    # no predictor is wired or the predictor abstains — ``work_len`` then
    # degrades to ``effective_len`` bit-for-bit.  ``session_id`` groups
    # requests from one conversation/agent loop (the empirical predictor's
    # strongest conditioning key); None for sessionless traffic.
    predicted_output: Optional[float] = None
    predicted_extra: Optional[float] = None
    session_id: Optional[int] = None

    # Lifecycle bookkeeping (filled in by the engine / simulator).
    state: RequestState = RequestState.WAITING
    terminal: Optional[TerminalState] = None  # stamped once, at exit
    # SLO-class label cache, stamped by the observability plane on first
    # classification (arrival) and reused at dispatch/finish so the label
    # is computed once per request.  Never read by scheduling code.
    slo_class: Optional[str] = None
    enqueue_time: float = 0.0               # when routed into a queue
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    generated: int = 0
    queue_id: Optional[int] = None
    preemptions: int = 0

    def wait_time(self, now: float) -> float:
        return max(0.0, now - self.arrival_time)

    @property
    def effective_len(self) -> float:
        """Prompt tokens that must actually be prefilled (the uncached
        suffix).  Equal to ``prompt_len`` whenever the KV plane is off
        (``cached_len`` 0), so every effective-length consumer degrades to
        the pre-KV-plane arithmetic bit-for-bit.  At least one token is
        always recomputed (a fully cached prompt still runs a 1-token
        prefill to produce its first logit)."""
        if self.cached_len <= 0:
            return float(self.prompt_len)
        return float(max(self.prompt_len - self.cached_len, 1))

    @property
    def work_len(self) -> float:
        """Predicted *total* effective work in prefill-equivalent tokens:
        the uncached prompt suffix plus the predictor's decode-side
        estimate (``predicted_extra``).  This is what EWSJF scores and
        queues on when a prediction plane is wired; with no prediction
        stamp it is exactly ``effective_len``, so every consumer degrades
        to the length-blind arithmetic bit-for-bit."""
        e = self.effective_len
        if self.predicted_extra is None:
            return e
        return e + self.predicted_extra

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def e2e_latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time


@dataclass(frozen=True)
class QueueBounds:
    """Closed prompt-length interval [lo, hi] owned by one queue."""

    lo: float
    hi: float

    def contains(self, b: float) -> bool:
        return self.lo <= b <= self.hi

    @property
    def width(self) -> float:
        return self.hi - self.lo

    @property
    def center(self) -> float:
        return 0.5 * (self.lo + self.hi)


@dataclass
class ScoringWeights:
    """Instantiated weights for one queue (Eq. 1 / Eq. 4)."""

    w_base: float = 1.0
    w_urgency: float = 1.0
    w_fairness: float = 1.0


@dataclass
class MetaParams:
    """Meta-policy parameters Θ tuned by the Bayesian optimizer (§4.4.2).

    Each scoring weight is produced by a linear map on the queue's mean
    prompt length  w(b̄_q) = a·b̄_q/B_norm + b , with B_norm a fixed length
    normalizer so the slopes are O(1).
    """

    a_urg: float = -0.5
    b_urg: float = 1.5
    a_fair: float = 0.8
    b_fair: float = 0.2
    a_base: float = 0.0
    b_base: float = 1.0
    alpha_split: float = 3.0        # Refine-and-Prune significance ratio α (Eq. 2)
    max_queues: int = 32            # Stage-3 pruning budget
    b_norm: float = 2048.0          # length normalizer for the meta-policy

    def as_vector(self) -> list[float]:
        return [self.a_urg, self.b_urg, self.a_fair, self.b_fair,
                self.a_base, self.b_base, self.alpha_split]

    @staticmethod
    def from_vector(v, max_queues: int = 32, b_norm: float = 2048.0) -> "MetaParams":
        return MetaParams(a_urg=float(v[0]), b_urg=float(v[1]),
                          a_fair=float(v[2]), b_fair=float(v[3]),
                          a_base=float(v[4]), b_base=float(v[5]),
                          alpha_split=float(v[6]),
                          max_queues=max_queues, b_norm=b_norm)


@dataclass
class SchedulerPolicy:
    """One complete policy emitted by the strategic loop (§3.1):
    queue structure (interval boundaries) + scoring meta-parameters."""

    boundaries: list[QueueBounds]
    meta: MetaParams

    def n_queues(self) -> int:
        return len(self.boundaries)


@dataclass
class QueueSnapshot:
    """Read-only view of one scheduler queue, exported for cluster routing
    (the router must see queue *structure*, not just totals)."""

    queue_id: int
    index: int                      # position in ascending-length order
    lo: float
    hi: float
    depth: int                      # waiting requests
    tokens: int                     # waiting prompt tokens
    mean_len: float                 # b̄_q
    head_len: Optional[float] = None
    head_wait: float = 0.0
    head_score: float = 0.0         # density-weighted score of the head

    def contains(self, length: float) -> bool:
        return self.lo <= length < self.hi or (
            self.hi == float("inf") and length >= self.lo)


@dataclass
class SchedulerSnapshot:
    """Cheap introspection view of a BaseScheduler, consumed by cluster-level
    routers.  Totals (`waiting`, `waiting_tokens`) support least-loaded
    policies; the per-queue list supports EWSJF-aware routing."""

    policy: str
    waiting: int
    waiting_tokens: int
    queues: list["QueueSnapshot"] = field(default_factory=list)

    def queue_for(self, length: float) -> Optional["QueueSnapshot"]:
        """The queue a request of ``length`` would route into (interval
        containment; falls back to the nearest queue by center)."""
        for q in self.queues:
            if q.contains(length):
                return q
        if not self.queues:
            return None
        return min(self.queues,
                   key=lambda q: abs(0.5 * (q.lo + min(q.hi, 2 * length))
                                     - length))


@dataclass
class BatchPlan:
    """What the tactical loop hands the engine for one step (Alg. 1 output)."""

    requests: list[Request]
    primary_queue: Optional[int] = None
    backfill_queues: list[int] = field(default_factory=list)
    total_tokens: int = 0
    padded_tokens: int = 0          # bucket-padded token count (TPU adaptation)

    @property
    def padding_waste(self) -> float:
        if self.padded_tokens <= 0:
            return 0.0
        return 1.0 - self.total_tokens / self.padded_tokens
