"""Batch construction — Algorithm 1 lines 15–23 (GreedyFill + Backfill).

The engine exposes a *budget* per scheduling tick:

    max_requests   — engine batch-slot limit (vLLM's max_num_seqs)
    max_tokens     — prefill token budget per step (chunked-prefill style)
    kv_blocks_free — paged-KV admission guard: a request is only admitted if
                     its prompt fits in the free block pool (vLLM semantics)

TPU adaptation (DESIGN.md §3): prefill batches are *bucketed* — all requests
in one batch are padded to the bucket edge of the primary queue.  Because an
EWSJF queue is performance-homogeneous, padding waste inside a batch is
small; `BatchPlan.padded_tokens` records the padded footprint so benchmarks
can quantify the effect vs FCFS.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from .queues import QueueManager, SchedulerQueue
from .types import BatchPlan, Request


@dataclass
class BatchBudget:
    max_requests: int = 64
    max_tokens: int = 8192
    kv_blocks_free: Optional[int] = None   # None = unconstrained
    block_size: int = 16
    pad_mode: bool = True      # TPU bucket padding: backfill may not raise
                               # the batch's bucket edge (GPU mode: no cap)

    def blocks_needed(self, req: Request) -> int:
        """KV blocks the request must newly allocate: its full paged
        footprint minus any cached prefix blocks it can share (KV plane;
        equal to the full footprint when cached_len is 0)."""
        total = -(-int(req.prompt_len) // self.block_size)
        if req.cached_len > 0:
            total -= int(req.cached_len) // self.block_size
        return max(total, 1)


def _bucket_edge(tokens: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if tokens <= b:
            return b
    return buckets[-1]


DEFAULT_BUCKETS = (128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768)


class BatchBuilder:
    """Greedy fill from the primary (argmax-score) queue, then backfill from
    adjacent queues while budget remains."""

    def __init__(self, budget: BatchBudget, buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 admit_fn: Optional[Callable[[Request], bool]] = None):
        self.budget = budget
        self.buckets = tuple(sorted(buckets))
        # Optional extra admission predicate from the engine (e.g. per-arch
        # context-length caps).
        self.admit_fn = admit_fn or (lambda r: True)

    def build(self, manager: QueueManager, primary: SchedulerQueue,
              now: float) -> BatchPlan:
        plan = BatchPlan(requests=[], primary_queue=primary.queue_id)
        free_blocks = self.budget.kv_blocks_free
        self._fill_from(primary, plan, free_blocks)
        # Backfill must preserve batch homogeneity (the whole point of the
        # partitioning): it may not raise the primary batch's bucket edge.
        # Only meaningful under TPU bucket padding; GPU mode has no edge.
        edge = (_bucket_edge(max(int(r.effective_len)
                                 for r in plan.requests), self.buckets)
                if plan.requests and self.budget.pad_mode else None)
        if len(plan.requests) < self.budget.max_requests and \
                plan.total_tokens < self.budget.max_tokens:
            for q in manager.adjacent_of(primary.queue_id):
                if not len(q):
                    continue
                took = self._fill_from(q, plan, free_blocks, max_len=edge)
                if took:
                    plan.backfill_queues.append(q.queue_id)
                if (len(plan.requests) >= self.budget.max_requests
                        or plan.total_tokens >= self.budget.max_tokens):
                    break
        # Bucket-pad to the largest member's bucket edge (one compiled shape
        # per batch => pad every row to the same edge).
        if plan.requests:
            edge = _bucket_edge(max(int(r.effective_len)
                                    for r in plan.requests), self.buckets)
            plan.padded_tokens = edge * len(plan.requests)
        return plan

    def _fill_from(self, q: SchedulerQueue, plan: BatchPlan,
                   free_blocks: Optional[int],
                   max_len: Optional[int] = None) -> int:
        took = 0
        while len(q):
            head = q.peek()
            if max_len is not None and head.effective_len > max_len:
                break
            if len(plan.requests) >= self.budget.max_requests:
                break
            if plan.total_tokens + head.effective_len > self.budget.max_tokens \
                    and plan.requests:
                break
            if free_blocks is not None:
                need = self.budget.blocks_needed(head)
                used = sum(self.budget.blocks_needed(r) for r in plan.requests)
                if used + need > free_blocks:
                    break
            if not self.admit_fn(head):
                break
            req = q.pop()
            plan.requests.append(req)
            plan.total_tokens += int(req.effective_len)
            took += 1
        return took
