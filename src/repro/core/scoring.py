"""Density-weighted, context-aware scoring (paper §4.1 / §4.4.1, Eq. 1/4).

    Φ(r, q) = qf · ( w_base + w_urg · cs + w_fair · log(b+1) )

with
    cs = W_t / C_prefill(b)      compute-normalized urgency,
    qf = q_i / (b̄ + 1)           SJF-inspired queue factor,
    b  = prompt length of the head-of-line request,
    b̄  = queue mean prompt length.

Weights are *context-aware*: produced by a linear meta-policy on the queue's
mean prompt length, e.g.  w_urg(b̄_q) = a_u · (b̄_q / B_norm) + b_u  — slopes
and intercepts are the meta-parameters Θ tuned by the Bayesian optimizer.

Conventions (these matter for the SJF behaviour and are unit-tested):

* Queue indices q_i count from *k down to 1* with q_1 = the longest-prompt
  queue...  The paper defines qf = q_i/(b̄+1) and says it "prioritizes
  shorter jobs".  With q_i ascending in prompt length the numerator would
  *favor long queues*; dividing by (b̄+1) restores the short bias.  We use
  ascending indices exactly as written — qf = (i+1)/(b̄+1) — since the
  (b̄+1) denominator dominates and yields the SJF bias the paper describes.
* Starvation freedom (Thm A.1): cs grows without bound in wait time, so any
  positive w_urg guarantees eventual scheduling.
"""

from __future__ import annotations

from dataclasses import dataclass
from math import log
from typing import Callable

from .types import MetaParams, Request, ScoringWeights


def weights_for_queue(meta: MetaParams, queue_mean_len: float) -> ScoringWeights:
    """Meta-policy π(b̄_q) → per-queue scoring weights (§4.4.1)."""
    x = queue_mean_len / max(meta.b_norm, 1.0)
    return ScoringWeights(
        w_base=max(0.0, meta.a_base * x + meta.b_base),
        w_urgency=max(1e-6, meta.a_urg * x + meta.b_urg),    # >0: Thm A.1
        w_fairness=max(0.0, meta.a_fair * x + meta.b_fair),
    )


@dataclass
class QueueProfile:
    """The per-queue statistics the scorer consumes (q.profile in Alg. 1)."""

    index: int                  # position in ascending-length queue order
    mean_len: float             # b̄_q — running mean of routed prompt lengths
    weights: ScoringWeights


def compute_score(req: Request, profile: QueueProfile, now: float,
                  c_prefill: Callable[[float], float]) -> float:
    """Score the head-of-line request of one queue (Eq. 1 / Eq. 4).

    ``b`` is the request's *work* length: the effective prompt length
    (uncached suffix, KV plane) plus the prediction plane's decode-side
    estimate in prefill-equivalent tokens.  A long prompt with a hot
    cached prefix competes like the short job it actually is; a short
    prompt predicted to generate 1k tokens competes like the long job it
    actually is.  Identical to raw ``prompt_len`` whenever ``cached_len``
    is 0 and no prediction is stamped."""
    b = req.work_len
    w = profile.weights
    wait = req.wait_time(now)
    cost = max(c_prefill(b), 1e-9)
    cs = wait / cost                                   # compute score
    qf = (profile.index + 1.0) / (profile.mean_len + 1.0)  # queue factor
    return qf * (w.w_base + w.w_urgency * cs + w.w_fairness * log(b + 1.0))


def score_decomposition(req: Request, profile: QueueProfile, now: float,
                        c_prefill: Callable[[float], float]) -> dict:
    """Expose each term for diagnostics / Figure-2-style plots."""
    b = req.work_len
    w = profile.weights
    cost = max(c_prefill(b), 1e-9)
    cs = req.wait_time(now) / cost
    qf = (profile.index + 1.0) / (profile.mean_len + 1.0)
    return {
        "qf": qf,
        "cs": cs,
        "base": w.w_base,
        "urgency": w.w_urgency * cs,
        "fairness": w.w_fairness * log(b + 1.0),
        "total": qf * (w.w_base + w.w_urgency * cs + w.w_fairness * log(b + 1.0)),
    }
