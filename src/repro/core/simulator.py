"""Discrete-event serving simulator (vLLM-style continuous batching).

Reproduces the paper's evaluation methodology on this CPU-only container:
the engine below EWSJF is modeled as a continuous-batching server with

  * a paged-KV block pool (admission requires the prompt to fit; decode
    growth can trigger recompute-mode preemption, as in vLLM),
  * chunked prefill with a per-step token budget,
  * multi-step decode between scheduling ticks (TPU adaptation: the
    scheduler tick is a step boundary; vLLM's --num-scheduler-steps),
  * bucket-padded prefill batches (TPU static shapes — the step time is
    charged on *padded* tokens, so homogeneous batches are cheaper).

Step times come from core/cost_model.py (TPU v5e roofline).  All results are
"simulator units" — the benchmarks reproduce the paper's *relative*
structure (speedups vs load/scale/queue-count), not A100 absolute numbers
(DESIGN.md §8).

The same Scheduler objects (core/scheduler.py) drive both this simulator and
the real JAX engine (serving/engine.py); only the executor differs.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .batch_builder import BatchBudget
from .cost_model import CostModel
from .scheduler import BaseScheduler
from .types import Request, RequestState


@dataclass
class EngineParams:
    max_num_seqs: int = 64              # decode slots
    max_prefill_tokens: int = 8192      # chunked-prefill budget per tick
    kv_pool_tokens: int = 131072        # paged-KV pool capacity
    block_size: int = 16
    decode_steps_per_tick: int = 8      # multi-step decode between ticks
    bucket_pad: bool = True             # TPU static-shape padding
    scheduler_overhead: float = 50e-6   # host-side tick cost (measured µs)
    # Client-abandonment SLO: a request whose TTFT wait exceeds this is
    # abandoned (producing nothing).  The paper's per-scheduler token totals
    # on identical workloads (Table 8: 320k FCFS vs 401k EWSJF) imply
    # exactly this overload behaviour; None disables.
    ttft_timeout: float | None = None

    @property
    def total_blocks(self) -> int:
        return self.kv_pool_tokens // self.block_size


@dataclass
class WorkloadSpec:
    """The paper's Mixed Workload: bimodal 32..4096, 80% short / 20% long,
    Poisson arrivals (§6.1)."""

    n_requests: int = 10_000
    arrival_rate: float = 20.0          # requests / s
    short_frac: float = 0.8
    short_range: tuple[int, int] = (32, 256)
    long_range: tuple[int, int] = (1024, 4096)
    mean_output_tokens: float = 11.0    # matches paper's tokens/request
    max_new_tokens: int = 128
    seed: int = 0

    def generate(self) -> list[Request]:
        rng = np.random.default_rng(self.seed)
        n = self.n_requests
        inter = rng.exponential(1.0 / self.arrival_rate, size=n)
        arrivals = np.cumsum(inter)
        is_short = rng.random(n) < self.short_frac
        lens = np.where(
            is_short,
            rng.integers(self.short_range[0], self.short_range[1] + 1, size=n),
            rng.integers(self.long_range[0], self.long_range[1] + 1, size=n))
        outs = np.clip(rng.geometric(1.0 / self.mean_output_tokens, size=n),
                       1, self.max_new_tokens)
        return [Request(prompt_len=int(lens[i]), arrival_time=float(arrivals[i]),
                        max_new_tokens=int(outs[i])) for i in range(n)]


def uniform_workload(n: int, lo: int, hi: int, rate: float, seed: int = 0,
                     mean_out: float = 11.0) -> list[Request]:
    """Single-regime workloads for Tables 8–9 (short-only / long-only)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    lens = rng.integers(lo, hi + 1, size=n)
    outs = np.clip(rng.geometric(1.0 / mean_out, size=n), 1, 128)
    return [Request(prompt_len=int(lens[i]), arrival_time=float(arrivals[i]),
                    max_new_tokens=int(outs[i])) for i in range(n)]


@dataclass
class SimResult:
    total_time: float
    finished: list[Request]
    preemptions: int
    ticks: int
    padded_prefill_tokens: int
    real_prefill_tokens: int
    busy_time: float
    aborted: list[Request] = field(default_factory=list)

    @property
    def abort_rate(self) -> float:
        n = len(self.finished) + len(self.aborted)
        return len(self.aborted) / max(n, 1)

    @property
    def req_per_s(self) -> float:
        return len(self.finished) / max(self.total_time, 1e-9)

    @property
    def tok_per_s(self) -> float:
        toks = sum(r.generated for r in self.finished)
        return toks / max(self.total_time, 1e-9)

    @property
    def padding_waste(self) -> float:
        if self.padded_prefill_tokens == 0:
            return 0.0
        return 1.0 - self.real_prefill_tokens / self.padded_prefill_tokens

    @property
    def utilization(self) -> float:
        return self.busy_time / max(self.total_time, 1e-9)

    def ttft_stats(self, short_threshold: int = 256) -> dict:
        ttfts = np.asarray([r.ttft for r in self.finished if r.ttft is not None])
        short = np.asarray([r.ttft for r in self.finished
                            if r.ttft is not None and r.prompt_len <= short_threshold])
        longs = np.asarray([r.ttft for r in self.finished
                            if r.ttft is not None and r.prompt_len > short_threshold])
        def s(a):
            if not len(a):
                return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
            return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
                    "p95": float(np.percentile(a, 95)),
                    "p99": float(np.percentile(a, 99))}
        return {"all": s(ttfts), "short": s(short), "long": s(longs)}


@dataclass
class _Running:
    req: Request
    kv_tokens: int          # KV held (prompt + generated)
    remaining: int          # output tokens still to produce


class ServingSimulator:
    """Event loop: arrivals → scheduler tick (admission) → prefill charge →
    multi-step decode charge → completions/preemptions → repeat."""

    def __init__(self, scheduler: BaseScheduler, cost: CostModel,
                 params: EngineParams | None = None,
                 on_dispatch=None):
        self.sched = scheduler
        self.cost = cost
        self.p = params or EngineParams()
        # Replay-harness hook: ``on_dispatch(requests, t)`` fires once per
        # tick whose admission plan survived abort filtering, before the
        # prefill charge — the DES side of the DES↔engine dispatch-order
        # equivalence check (serving/replay.py).  Pure observation.
        self.on_dispatch = on_dispatch

    def run(self, requests: list[Request], max_sim_time: float = 1e7) -> SimResult:
        p = self.p
        arrivals = sorted(requests, key=lambda r: r.arrival_time)
        ai = 0
        t = 0.0
        busy = 0.0
        running: list[_Running] = []
        free_blocks = p.total_blocks
        finished: list[Request] = []
        aborted: list[Request] = []
        preemptions = 0
        ticks = 0
        padded_total = 0
        real_total = 0
        n_total = len(arrivals)

        def blocks_for(tokens: int) -> int:
            return -(-tokens // p.block_size)

        while len(finished) + len(aborted) < n_total and t < max_sim_time:
            # 1) deliver arrivals up to current time
            while ai < n_total and arrivals[ai].arrival_time <= t:
                self.sched.submit(arrivals[ai], now=t)
                ai += 1
            # idle fast-forward if nothing to do
            if not running and self.sched.waiting() == 0:
                if ai < n_total:
                    t = arrivals[ai].arrival_time
                    continue
                break

            self.sched.maybe_reoptimize(t) if hasattr(
                self.sched, "maybe_reoptimize") else None
            ticks += 1
            t += p.scheduler_overhead

            # 2) admission
            budget = BatchBudget(
                max_requests=p.max_num_seqs - len(running),
                max_tokens=p.max_prefill_tokens,
                kv_blocks_free=free_blocks,
                block_size=p.block_size,
                pad_mode=p.bucket_pad)
            plan = (self.sched.tick(t, budget)
                    if budget.max_requests > 0 else None)
            if plan and plan.requests and p.ttft_timeout is not None:
                live = []
                for r in plan.requests:
                    if r.wait_time(t) > p.ttft_timeout:
                        r.state = RequestState.FAILED
                        r.finish_time = t
                        aborted.append(r)
                    else:
                        live.append(r)
                plan.requests = live
                plan.total_tokens = sum(int(r.prompt_len) for r in live)

            # 3) prefill charge
            if plan and plan.requests:
                if self.on_dispatch is not None:
                    self.on_dispatch(plan.requests, t)
                batch_tokens = plan.total_tokens
                padded = plan.padded_tokens if p.bucket_pad else batch_tokens
                padded = max(padded, batch_tokens)
                mean_ctx = batch_tokens / len(plan.requests)
                dt = self.cost.prefill_step_time(padded, mean_ctx)
                t += dt
                busy += dt
                padded_total += padded
                real_total += batch_tokens
                for r in plan.requests:
                    free_blocks -= blocks_for(r.prompt_len)
                    r.state = RequestState.RUNNING_DECODE
                    r.first_token_time = t          # first token at prefill end
                    r.generated = 1
                    rem = max(r.max_new_tokens - 1, 0)
                    if rem == 0:
                        self._finish(r, t, finished)
                        free_blocks += blocks_for(r.prompt_len)
                    else:
                        running.append(_Running(r, r.prompt_len + 1, rem))

            # 4) decode: up to decode_steps_per_tick token steps
            for _ in range(p.decode_steps_per_tick):
                if not running:
                    break
                # growth-block check → recompute-mode preemption (LIFO)
                need = sum(1 for rr in running
                           if (rr.kv_tokens % p.block_size) == 0)
                while need > free_blocks and len(running) > 1:
                    victim = running.pop()            # most recent admitted
                    free_blocks += blocks_for(victim.kv_tokens)
                    victim.req.state = RequestState.PREEMPTED
                    victim.req.preemptions += 1
                    victim.req.generated = 0
                    victim.req.first_token_time = None
                    self.sched.submit(victim.req, now=t)
                    preemptions += 1
                    need = sum(1 for rr in running
                               if (rr.kv_tokens % p.block_size) == 0)
                total_kv = sum(rr.kv_tokens for rr in running)
                dt = self.cost.decode_step_time(len(running), total_kv)
                t += dt
                busy += dt
                done_idx = []
                for i, rr in enumerate(running):
                    if rr.kv_tokens % p.block_size == 0:
                        free_blocks -= 1
                    rr.kv_tokens += 1
                    rr.req.generated += 1
                    rr.remaining -= 1
                    if rr.remaining <= 0:
                        done_idx.append(i)
                for i in reversed(done_idx):
                    rr = running.pop(i)
                    free_blocks += blocks_for(rr.kv_tokens)
                    self._finish(rr.req, t, finished)

            # 5) if nothing could run, jump to next arrival
            if (plan is None or not plan.requests) and not running:
                if ai < n_total:
                    t = max(t, arrivals[ai].arrival_time)

        return SimResult(total_time=t, finished=finished,
                         preemptions=preemptions, ticks=ticks,
                         padded_prefill_tokens=padded_total,
                         real_prefill_tokens=real_total, busy_time=busy,
                         aborted=aborted)

    def _finish(self, req: Request, t: float, finished: list[Request]) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = t
        finished.append(req)
        self.sched.on_finish(req, t)


def run_comparison(schedulers: dict[str, BaseScheduler],
                   workload: WorkloadSpec | list[Request],
                   cost: CostModel, params: EngineParams | None = None
                   ) -> dict[str, SimResult]:
    """Run the same workload through multiple schedulers (fresh copies of
    the request list each time)."""
    import copy
    base = workload.generate() if isinstance(workload, WorkloadSpec) else workload
    out = {}
    for name, sched in schedulers.items():
        reqs = copy.deepcopy(base)
        sim = ServingSimulator(sched, cost, params)
        out[name] = sim.run(reqs)
    return out
