"""Refine-and-Prune — the strategic partitioning core of EWSJF (§4.2).

Given the sorted prompt lengths observed in the strategic window, produce a
set of contiguous, non-overlapping prompt-length intervals ("queues") that
are (i) performance-homogeneous, (ii) contiguous, (iii) bounded in number.

Three stages, exactly as in the paper:

  Stage 1  Coarse partitioning      — 1-D k-means, k=3 (short/medium/long).
  Stage 2  Recursive refinement     — split a cluster at gap j whenever
                                      Gap_j > α · mean(G)            (Eq. 2)
                                      until no significant gap remains or the
                                      cluster is below the min-width floor.
  Stage 3  Intelligent pruning      — merge the adjacent pair with the lowest
                                      Scheduling Utility
                                      U = (ρ_i + ρ_{i+1}) / (|b̄_{i+1}−b̄_i|+ε)
                                      (Eq. 3) until ≤ max_queues remain.

The output intervals tile the *full* observed range with no holes: each
cluster's interval is extended to the midpoint of the inter-cluster gap so
that routing (core/queues.py) is a total function.  Requests beyond the
observed range route to the first/last queue; genuinely new in-gap regimes
are handled by bubble queues at dispatch time (§4.3).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .types import QueueBounds


@dataclass(frozen=True)
class PartitionConfig:
    alpha_split: float = 3.0        # Eq. 2 significance ratio (meta-tuned)
    max_queues: int = 32
    min_width: int = 8              # min interval width for further splitting
    min_cluster_size: int = 4       # don't split clusters smaller than this
    coarse_k: int = 3               # Stage-1 anchors (short/medium/long)
    eps: float = 1e-6               # Eq. 3 numerical-stability constant
    kmeans_iters: int = 32


# --------------------------------------------------------------------------
# Stage 1: coarse 1-D k-means
# --------------------------------------------------------------------------

def kmeans_1d(values: np.ndarray, k: int, iters: int = 32,
              seed: int = 0) -> list[np.ndarray]:
    """Plain 1-D k-means on sorted values; returns list of contiguous
    clusters (sorted by center).  Deterministic: quantile init."""
    values = np.sort(np.asarray(values, dtype=np.float64))
    n = len(values)
    if n == 0:
        return []
    k = min(k, len(np.unique(values)))
    if k <= 1:
        return [values]
    # Quantile initialization keeps centers ordered and deterministic.
    centers = np.quantile(values, (np.arange(k) + 0.5) / k)
    for _ in range(iters):
        # 1-D assignment = nearest center; with sorted centers this is a
        # thresholding at midpoints, keeping clusters contiguous.
        mids = (centers[:-1] + centers[1:]) / 2.0
        idx = np.searchsorted(mids, values, side="right")
        new_centers = centers.copy()
        for j in range(k):
            sel = values[idx == j]
            if len(sel):
                new_centers[j] = sel.mean()
        if np.allclose(new_centers, centers):
            break
        centers = np.sort(new_centers)
    mids = (centers[:-1] + centers[1:]) / 2.0
    idx = np.searchsorted(mids, values, side="right")
    return [values[idx == j] for j in range(k) if np.any(idx == j)]


# --------------------------------------------------------------------------
# Stage 2: recursive gap refinement
# --------------------------------------------------------------------------

def refine_cluster(cluster: np.ndarray, cfg: PartitionConfig) -> list[np.ndarray]:
    """Split ``cluster`` (sorted 1-D array) at significant gaps (Eq. 2),
    recursing on both halves.  Iterative worklist form — the recursive
    formulation overflows Python's stack at N=100k histories."""
    out: list[np.ndarray] = []
    work = [cluster]
    while work:
        c = work.pop()
        if (len(c) < cfg.min_cluster_size
                or c[-1] - c[0] < cfg.min_width):
            out.append(c)
            continue
        gaps = np.diff(c)
        mean_gap = gaps.mean() if len(gaps) else 0.0
        if mean_gap <= 0:
            out.append(c)
            continue
        j = int(np.argmax(gaps))
        if gaps[j] > cfg.alpha_split * mean_gap:      # Eq. 2
            work.append(c[: j + 1])
            work.append(c[j + 1:])
        else:
            out.append(c)
    out.sort(key=lambda c: float(c[0]))
    return out


# --------------------------------------------------------------------------
# Stage 3: utility-based pruning (merging)
# --------------------------------------------------------------------------

def scheduling_utility(c1: np.ndarray, c2: np.ndarray, eps: float) -> float:
    """Eq. 3: U(q_i, q_{i+1}) = (ρ_i + ρ_{i+1}) / (|b̄_{i+1} − b̄_i| + ε).

    ρ(q) — request density — requests per unit of interval width."""
    def density(c: np.ndarray) -> float:
        width = max(float(c[-1] - c[0]), 1.0)
        return len(c) / width
    return (density(c1) + density(c2)) / (abs(float(c2.mean() - c1.mean())) + eps)


def prune_clusters(clusters: list[np.ndarray], cfg: PartitionConfig) -> list[np.ndarray]:
    """Merge adjacent pairs by Scheduling Utility until ≤ max_queues remain.

    INTERPRETATION NOTE (DESIGN.md §8): Eq. 3's U = (ρ_i+ρ_j)/(Δb̄+ε) is a
    merge *affinity* — highest for dense, nearby pairs, i.e. pairs whose
    separation buys the least scheduling value.  The paper's prose says
    "queues with the lowest utility are merged", but merging the lowest-U
    (sparse, far-apart) pairs empirically reproduces exactly the
    mega-queue + micro-queue pathology Table 2 says EWSJF avoids (on dense
    integer length data every unit gap survives as its own queue).  We
    therefore merge the *highest-affinity* pair first, which yields the
    intended behaviour: micro-queues collapse, distinct regimes survive."""
    clusters = [c for c in clusters if len(c)]
    if len(clusters) <= cfg.max_queues:
        return clusters
    # Incremental merge: recompute only the utilities adjacent to each
    # merge (the naive re-scan is O(m^2) and dominates at 100k histories).
    utils = [scheduling_utility(clusters[i], clusters[i + 1], cfg.eps)
             for i in range(len(clusters) - 1)]
    while len(clusters) > cfg.max_queues:
        i = int(np.argmax(utils))
        merged = np.concatenate([clusters[i], clusters[i + 1]])
        clusters[i: i + 2] = [merged]
        del utils[i]
        if i > 0:
            utils[i - 1] = scheduling_utility(clusters[i - 1], clusters[i],
                                              cfg.eps)
        if i < len(clusters) - 1:
            utils[i] = scheduling_utility(clusters[i], clusters[i + 1],
                                          cfg.eps)
    return clusters


# --------------------------------------------------------------------------
# Full pipeline
# --------------------------------------------------------------------------

def refine_and_prune(prompt_lengths, cfg: PartitionConfig | None = None
                     ) -> list[QueueBounds]:
    """Run the full Refine-and-Prune pipeline; returns interval bounds that
    tile [min(D), max(D)] contiguously (gap midpoints assigned to the nearer
    side implicitly by splitting at the midpoint)."""
    cfg = cfg or PartitionConfig()
    values = np.sort(np.asarray(list(prompt_lengths), dtype=np.float64))
    if len(values) == 0:
        return [QueueBounds(0.0, float("inf"))]

    # Stage 1 — coarse anchors.
    clusters = kmeans_1d(values, cfg.coarse_k, cfg.kmeans_iters)
    # Stage 2 — recursive refinement inside each anchor.
    refined: list[np.ndarray] = []
    for c in clusters:
        refined.extend(refine_cluster(np.sort(c), cfg))
    refined = [c for c in refined if len(c)]
    refined.sort(key=lambda c: float(c[0]))
    # Stage 3 — utility pruning to the queue budget.
    pruned = prune_clusters(refined, cfg)
    # Stage 3b — budget fill: gap-splitting finds no structure inside smooth
    # regimes, but queue granularity is itself scheduling value (the paper's
    # Table 3: throughput rises to the 32-queue budget; Refine-and-Prune
    # "identifies 32 queues as optimal").  Subdivide the most populous
    # clusters at their median until the budget is met (DESIGN.md §8).
    pruned = fill_budget(pruned, cfg)

    return clusters_to_bounds(pruned)


def fill_budget(clusters: list[np.ndarray], cfg: PartitionConfig
                ) -> list[np.ndarray]:
    clusters = list(clusters)
    while len(clusters) < cfg.max_queues:
        idx = max(range(len(clusters)), key=lambda i: len(clusters[i]))
        c = clusters[idx]
        if (len(c) < 2 * cfg.min_cluster_size
                or c[-1] - c[0] < 2 * cfg.min_width):
            break
        mid = len(c) // 2
        # split at the median *value* boundary (keep equal values together)
        v = c[mid]
        left = c[c < v]
        right = c[c >= v]
        if len(left) == 0 or len(right) == 0:
            break
        clusters[idx: idx + 1] = [left, right]
    return clusters


def clusters_to_bounds(clusters: list[np.ndarray]) -> list[QueueBounds]:
    """Convert contiguous clusters to hole-free interval bounds by splitting
    each inter-cluster gap at its midpoint."""
    if not clusters:
        return [QueueBounds(0.0, float("inf"))]
    edges = [0.0]
    for c1, c2 in zip(clusters[:-1], clusters[1:]):
        edges.append(0.5 * (float(c1[-1]) + float(c2[0])))
    edges.append(float("inf"))
    return [QueueBounds(edges[i], edges[i + 1]) for i in range(len(clusters))]


def pooled_lengths(pools, weights=None, cap: int = 50_000,
                   seed: int = 0) -> np.ndarray:
    """Weighted pooling of per-replica length samples (fleet-level strategic
    plane).  Each pool is resampled to a share of ``cap`` proportional to its
    weight (its replica's true arrival count, not the capped sample size), so
    high-traffic replicas dominate the merged distribution while the merge
    cost stays bounded regardless of fleet size.  Deterministic given
    ``seed``."""
    pools = [np.asarray(p, dtype=np.float64) for p in pools]
    if weights is None:
        w = np.asarray([len(p) for p in pools], dtype=np.float64)
    else:
        w = np.asarray(list(weights), dtype=np.float64)
        if len(w) != len(pools):
            raise ValueError(f"{len(weights)} weights for {len(pools)} pools")
    # drop empty pools *and their weights together* so an explicit weight
    # list stays aligned with the pools it describes
    keep = [i for i, p in enumerate(pools) if len(p)]
    pools = [pools[i] for i in keep]
    if not pools:
        return np.empty(0, dtype=np.float64)
    w = np.where(w[keep] > 0, w[keep], 0.0)
    if w.sum() <= 0:
        w = np.asarray([len(p) for p in pools], dtype=np.float64)
    total = int(min(cap, sum(len(p) for p in pools)))
    shares = np.maximum(1, np.round(total * w / w.sum()).astype(int))
    rng = np.random.default_rng(seed)
    parts = []
    for p, n in zip(pools, shares):
        if len(p) <= n:
            parts.append(p)                    # keep everything we have
        else:
            parts.append(rng.choice(p, size=n, replace=False))
    return np.sort(np.concatenate(parts))


def weighted_refine_and_prune(pools, weights=None,
                              cfg: PartitionConfig | None = None,
                              cap: int = 50_000, seed: int = 0
                              ) -> list[QueueBounds]:
    """Fleet-level Refine-and-Prune: merge per-replica length distributions
    (weighted by each replica's arrival volume) and partition the pooled
    distribution.  This is the global half of the shared policy store — a
    single queue structure every replica can adopt."""
    return refine_and_prune(pooled_lengths(pools, weights, cap=cap,
                                           seed=seed), cfg)


def edge_divergence(local_edges, global_edges) -> float | None:
    """Mean relative distance from each local interior edge to its nearest
    global one — the one divergence definition shared by the policy store
    (operator signal), the EWSJF router (alignment penalty), and the
    policy-store benchmark.  Infinite edges are ignored; returns None when
    either side has no finite interior edges (no structure to compare)."""
    g = np.asarray([e for e in global_edges if e != float("inf")],
                   dtype=np.float64)
    loc = [e for e in local_edges if e != float("inf")]
    if not len(g) or not loc:
        return None
    return float(np.mean([np.min(np.abs(g - e)) / max(e, 1.0)
                          for e in loc]))


def kmeans_partition(prompt_lengths, k: int) -> list[QueueBounds]:
    """Baseline partitioner: plain k-means with fixed k (paper Table 3's
    'EWSJF (K-Means)' rows)."""
    values = np.sort(np.asarray(list(prompt_lengths), dtype=np.float64))
    if len(values) == 0:
        return [QueueBounds(0.0, float("inf"))]
    clusters = kmeans_1d(values, k)
    return clusters_to_bounds(clusters)


def static_partition(lo: float, hi: float, k: int) -> list[QueueBounds]:
    """Baseline: fixed uniform-width buckets (the 'STATIC' row in Table 2)."""
    edges = np.linspace(lo, hi, k + 1)
    bounds = [QueueBounds(float(edges[i]), float(edges[i + 1]))
              for i in range(k)]
    return ([QueueBounds(0.0, bounds[0].hi)] + bounds[1:-1]
            + [QueueBounds(bounds[-1].lo, float("inf"))]) if k >= 2 else \
        [QueueBounds(0.0, float("inf"))]


def validate_partition(bounds: list[QueueBounds]) -> None:
    """Invariants (tested by hypothesis): contiguous, non-overlapping,
    monotonically ordered, covering [0, inf)."""
    assert bounds, "empty partition"
    assert bounds[0].lo == 0.0
    assert bounds[-1].hi == float("inf")
    for a, b in zip(bounds[:-1], bounds[1:]):
        assert a.hi == b.lo, f"hole or overlap between {a} and {b}"
        assert a.lo < a.hi
