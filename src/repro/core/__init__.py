"""EWSJF core — the paper's contribution (adaptive request-level scheduling).

Public API:
    Request, QueueBounds, MetaParams, SchedulerPolicy, BatchPlan
    refine_and_prune, kmeans_partition, PartitionConfig
    EWSJFScheduler, FCFSScheduler, SJFScheduler, make_scheduler
    BayesianMetaOptimizer
    CostModel, ServingSimulator, WorkloadSpec
"""

from .batch_builder import BatchBudget, BatchBuilder, DEFAULT_BUCKETS
from .cost_model import CostModel, ModelCostParams, make_cost_fn
from .meta_optimizer import BayesianMetaOptimizer
from .monitor import Monitor, RewardWeights, reward, reward_terms
from .partition import (PartitionConfig, edge_divergence, kmeans_partition,
                        pooled_lengths, refine_and_prune, static_partition,
                        validate_partition, weighted_refine_and_prune)
from .queues import BubbleConfig, QueueManager, SchedulerQueue
from .scheduler import (BaseScheduler, EWSJFConfig, EWSJFScheduler,
                        FCFSScheduler, SJFScheduler, StaticPriorityScheduler,
                        make_scheduler)
from .scoring import QueueProfile, compute_score, score_decomposition, weights_for_queue
from .simulator import (EngineParams, ServingSimulator, SimResult,
                        WorkloadSpec, run_comparison, uniform_workload)
from .types import (BatchPlan, MetaParams, QueueBounds, QueueSnapshot,
                    Request, RequestState, SchedulerPolicy, SchedulerSnapshot,
                    ScoringWeights, TerminalState)

__all__ = [
    "BatchBudget", "BatchBuilder", "DEFAULT_BUCKETS",
    "CostModel", "ModelCostParams", "make_cost_fn",
    "BayesianMetaOptimizer",
    "Monitor", "RewardWeights", "reward", "reward_terms",
    "PartitionConfig", "edge_divergence", "kmeans_partition", "pooled_lengths",
    "refine_and_prune", "static_partition", "validate_partition",
    "weighted_refine_and_prune",
    "BubbleConfig", "QueueManager", "SchedulerQueue",
    "BaseScheduler", "EWSJFConfig", "EWSJFScheduler", "FCFSScheduler",
    "SJFScheduler", "StaticPriorityScheduler", "make_scheduler",
    "QueueProfile", "compute_score", "score_decomposition", "weights_for_queue",
    "EngineParams", "ServingSimulator", "SimResult", "WorkloadSpec",
    "run_comparison", "uniform_workload",
    "BatchPlan", "MetaParams", "QueueBounds", "QueueSnapshot", "Request",
    "RequestState", "SchedulerPolicy", "SchedulerSnapshot", "ScoringWeights",
    "TerminalState",
]
