"""Scheduler policies: EWSJF (the paper) + FCFS / SJF / static-priority
baselines, behind one pluggable interface (the vLLM-RFC-style plug point).

`SchedulerPolicy.tick(now, budget)` is the tactical loop — called by the
engine (or simulator) at every scheduling opportunity; it returns a
BatchPlan.  `submit(req)` routes arrivals.  The strategic loop runs via
`maybe_reoptimize(now)`, which (a) refreshes the queue structure with
Refine-and-Prune on the monitor's window and (b) advances the Bayesian
meta-optimizer one trial when the trial interval elapses.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from math import log
from typing import Callable, Optional

import numpy as np

from .batch_builder import BatchBudget, BatchBuilder
from .cost_model import CostModel, make_cost_fn
from .meta_optimizer import BayesianMetaOptimizer
from .monitor import Monitor, RewardWeights, reward, reward_terms
from .partition import PartitionConfig, refine_and_prune
from .queues import QueueManager, SchedulerQueue
from .scoring import QueueProfile, compute_score, weights_for_queue
from .types import (BatchPlan, MetaParams, QueueBounds, QueueSnapshot,
                    Request, SchedulerPolicy, SchedulerSnapshot)


class BaseScheduler:
    """Interface every admission policy implements."""

    name = "base"
    # Monotonic mutation counter: bumped (via ``_publish``) whenever the
    # queue state visible through ``snapshot()`` changes.  Cluster-level
    # caches (router cost memos, replica snapshot caches) key on it for
    # event-driven invalidation instead of rebuilding per arrival.
    version = 0
    # Epoch of the last fleet policy adopted from a shared PolicyStore
    # (−1 = never; only policies implementing ``adopt_global_policy``
    # participate in fleet-level sync).
    adopted_epoch = -1
    # Optional output-length predictor (repro.predict.LengthPredictor),
    # wired by the cluster simulator.  The scheduler itself never calls it
    # on the hot path — requests arrive already stamped (work_len); the
    # attribute exists so the fleet policy store can publish/absorb the
    # predictor's posterior alongside the scheduling policy.
    predictor = None

    def _publish(self) -> None:
        """Delta-publication hook: mark the scheduler state as changed."""
        self.version = self.version + 1

    def submit(self, req: Request, now: float) -> None:
        raise NotImplementedError

    def tick(self, now: float, budget: BatchBudget) -> BatchPlan:
        raise NotImplementedError

    def on_finish(self, req: Request, now: float) -> None:  # optional hook
        pass

    def waiting(self) -> int:
        raise NotImplementedError

    def snapshot(self, now: float) -> SchedulerSnapshot:
        """Introspection view for cluster-level routing (queue structure +
        head scores).  The default reports totals only (`waiting()`, no
        per-queue structure) so any policy stays routable; subclasses
        should override with real structure — FCFSScheduler reports one
        pseudo-queue spanning [0, inf), EWSJFScheduler its live partition."""
        return SchedulerSnapshot(policy=self.name, waiting=self.waiting(),
                                 waiting_tokens=0, queues=[])

    def snapshot_cached(self, now: float) -> SchedulerSnapshot:
        """Like ``snapshot`` but allowed to reuse incrementally-maintained
        state between mutations (same values, cheaper).  Policies without an
        incremental view fall back to a fresh build."""
        return self.snapshot(now)

    def drain(self) -> list[Request]:
        """Remove and return every waiting request.  Required by the
        cluster layer for replica failure / straggler re-routing; policies
        that cannot enumerate their queue cannot be failed over."""
        raise NotImplementedError

    def state_dict(self) -> dict:            # checkpointing hook
        return {}

    def load_state_dict(self, state: dict) -> None:
        pass


# --------------------------------------------------------------------------
# Baselines
# --------------------------------------------------------------------------

class FCFSScheduler(BaseScheduler):
    """vLLM default: single FIFO queue."""

    name = "fcfs"

    def __init__(self):
        self.queue: list[Request] = []
        self._tok_sum = 0

    def submit(self, req: Request, now: float) -> None:
        req.enqueue_time = now
        self.queue.append(req)
        self._tok_sum += int(req.effective_len)
        self._publish()

    def tick(self, now: float, budget: BatchBudget) -> BatchPlan:
        plan = BatchPlan(requests=[])
        free = budget.kv_blocks_free
        used = 0
        while self.queue and len(plan.requests) < budget.max_requests:
            head = self.queue[0]
            if plan.requests and plan.total_tokens + head.effective_len \
                    > budget.max_tokens:
                break
            if free is not None:
                need = budget.blocks_needed(head)
                if used + need > free:
                    break
                used += need
            plan.requests.append(self.queue.pop(0))
            plan.total_tokens += int(head.effective_len)
            self._tok_sum -= int(head.effective_len)
        if plan.requests:
            self._publish()
            from .batch_builder import DEFAULT_BUCKETS, _bucket_edge
            edge = _bucket_edge(max(int(r.effective_len)
                                    for r in plan.requests), DEFAULT_BUCKETS)
            plan.padded_tokens = edge * len(plan.requests)
        return plan

    def waiting(self) -> int:
        return len(self.queue)

    def snapshot(self, now: float) -> SchedulerSnapshot:
        tokens = self._tok_sum
        head = self.queue[0] if self.queue else None
        mean = tokens / len(self.queue) if self.queue else 0.0
        q = QueueSnapshot(
            queue_id=0, index=0, lo=0.0, hi=float("inf"),
            depth=len(self.queue), tokens=tokens, mean_len=mean,
            head_len=head.effective_len if head else None,
            head_wait=head.wait_time(now) if head else 0.0,
            # FIFO has no density weighting: the head's "score" is its wait.
            head_score=head.wait_time(now) if head else 0.0)
        return SchedulerSnapshot(policy=self.name, waiting=len(self.queue),
                                 waiting_tokens=tokens, queues=[q])

    def drain(self) -> list[Request]:
        out, self.queue = self.queue, []
        self._tok_sum = 0
        self._publish()
        return out


class SJFScheduler(FCFSScheduler):
    """Greedy shortest-job-first (App. C starvation baseline)."""

    name = "sjf"

    def tick(self, now: float, budget: BatchBudget) -> BatchPlan:
        self.queue.sort(key=lambda r: (r.work_len, r.arrival_time))
        return super().tick(now, budget)


class StaticPriorityScheduler(FCFSScheduler):
    """Coarse two-class static priority (short first), the 'static queues'
    strawman from §1."""

    name = "static_priority"

    def __init__(self, short_threshold: int = 256):
        super().__init__()
        self.short_threshold = short_threshold

    def tick(self, now: float, budget: BatchBudget) -> BatchPlan:
        self.queue.sort(key=lambda r: (r.work_len > self.short_threshold,
                                       r.arrival_time))
        return super().tick(now, budget)


# --------------------------------------------------------------------------
# EWSJF
# --------------------------------------------------------------------------

@dataclass
class EWSJFConfig:
    max_queues: int = 32
    empty_threshold: int = 50
    history_cap: int = 200_000
    reopt_interval: float = 60.0        # strategic Refine-and-Prune period (s)
    trial_interval: float = 120.0       # Bayesian-optimizer trial length ΔT (s)
    min_history: int = 64               # don't re-partition before this
    short_threshold: float = 256.0
    online_blend: float = 0.25          # online-mode boundary smoothing
    enable_meta_opt: bool = True
    enable_bubbles: bool = True
    reward_weights: RewardWeights = field(default_factory=RewardWeights)
    seed: int = 0


class EWSJFScheduler(BaseScheduler):
    """The paper's scheduler: Refine-and-Prune queues + density-weighted
    scoring + bubble routing + Bayesian meta-optimization."""

    name = "ewsjf"

    def __init__(self, cfg: EWSJFConfig | None = None,
                 cost_model: CostModel | None = None,
                 initial_policy: Optional[SchedulerPolicy] = None,
                 partitioner: Optional[Callable] = None):
        self.cfg = cfg or EWSJFConfig()
        self.cost_model = cost_model or CostModel()
        self.c_prefill = make_cost_fn(self.cost_model)
        self.monitor = Monitor(history_cap=self.cfg.history_cap,
                               short_threshold=self.cfg.short_threshold)
        self.meta_opt = BayesianMetaOptimizer(seed=self.cfg.seed,
                                              max_queues=self.cfg.max_queues)
        self.partitioner = partitioner  # override for k-means ablations
        meta = (initial_policy.meta if initial_policy
                else MetaParams(max_queues=self.cfg.max_queues))
        bounds = (initial_policy.boundaries if initial_policy
                  else [QueueBounds(0.0, float("inf"))])
        self.manager = QueueManager(bounds, meta,
                                    empty_threshold=self.cfg.empty_threshold)
        self._last_reopt = 0.0
        self._trial_start = 0.0
        self._trial_meta: Optional[MetaParams] = None
        self._trial_finish_mark = 0
        self._trial_token_mark = 0
        self.tick_count = 0
        self.reopt_count = 0
        # reopt_count at the moment of the last fleet-policy adoption: the
        # policy store re-broadcasts (same epoch) once this falls behind,
        # so local repartitions between epochs still get re-aligned.
        self._reopt_at_adopt = -1
        # Incrementally-maintained snapshot (cluster routing cache): rebuilt
        # only on structural changes, patched in place on submit/dispatch,
        # head scores refreshed lazily per access time.
        self._snap: Optional[SchedulerSnapshot] = None
        self._snap_entries: list[tuple[QueueSnapshot, SchedulerQueue]] = []
        self._snap_by_id: dict[int, int] = {}        # queue_id -> entry index
        self._snap_ids: tuple[int, ...] = ()
        self._snap_profiles: dict[int, QueueProfile] = {}
        # Per-queue head-score coefficients: the head request only changes on
        # a published delta, and between deltas its score is *affine in
        # time* — Φ = qf·(w_base + w_fair·log(b+1)) + qf·w_urg/C(b) · wait —
        # so refresh is O(1) per queue with no cost-model calls.
        # Entry: (head_arrival, head_len, base, slope) or None when empty.
        self._snap_coeffs: list[Optional[tuple[float, float, float, float]]] = []
        self._snap_time: Optional[float] = None

    # ---- request path ----------------------------------------------------

    def submit(self, req: Request, now: float) -> None:
        req.enqueue_time = now
        self.monitor.observe_arrival(req)
        if self.cfg.enable_bubbles:
            self.manager.route(req)
        else:
            q = self.manager.queues[
                self.manager._find_interval(req.work_len)]
            q.push(req)
            req.queue_id = q.queue_id
        self._snapshot_delta([req.queue_id] if req.queue_id is not None
                             else [])

    def on_finish(self, req: Request, now: float) -> None:
        self.monitor.observe_finish(req)

    def waiting(self) -> int:
        return self.manager.waiting_count()

    def snapshot(self, now: float) -> SchedulerSnapshot:
        profiles = self.manager.profiles()
        queues: list[QueueSnapshot] = []
        total_reqs = 0
        total_tokens = 0
        for i, q in enumerate(self.manager.queues):
            tokens = sum(int(r.work_len) for r in q.requests)
            head = q.peek()
            queues.append(QueueSnapshot(
                queue_id=q.queue_id, index=i,
                lo=q.bounds.lo, hi=q.bounds.hi,
                depth=len(q), tokens=tokens, mean_len=q.mean_len,
                head_len=head.work_len if head else None,
                head_wait=head.wait_time(now) if head else 0.0,
                head_score=(compute_score(head, profiles[q.queue_id], now,
                                          self.c_prefill) if head else 0.0)))
            total_reqs += len(q)
            total_tokens += tokens
        return SchedulerSnapshot(policy=self.name, waiting=total_reqs,
                                 waiting_tokens=total_tokens, queues=queues)

    def drain(self) -> list[Request]:
        out: list[Request] = []
        for q in self.manager.queues:
            out.extend(q.clear_requests())
        self._mark_snapshot_dirty()
        return out

    # ---- incremental snapshot (cluster routing cache) ----------------------

    def _mark_snapshot_dirty(self) -> None:
        """Structural change (repartition / bubble / prune / drain): the
        cached snapshot must be rebuilt from scratch on next access."""
        self._snap = None
        self._publish()

    def _head_coeff(self, q: SchedulerQueue
                    ) -> Optional[tuple[float, float, float, float]]:
        head = q.peek()
        if head is None:
            return None
        p = self._snap_profiles[q.queue_id]
        w = p.weights
        b = head.work_len
        cost = max(self.c_prefill(b), 1e-9)
        qf = (p.index + 1.0) / (p.mean_len + 1.0)
        base = qf * (w.w_base + w.w_fairness * log(b + 1.0))
        slope = qf * w.w_urgency / cost
        return (head.arrival_time, b, base, slope)

    def _snapshot_delta(self, queue_ids) -> None:
        """Patch the cached snapshot after a local change (enqueue or
        dispatch touching ``queue_ids``).  Falls back to a full rebuild flag
        when the queue *structure* changed underneath (new bubble, prune,
        repartition)."""
        self._publish()
        if self._snap is None:
            return
        if tuple(q.queue_id for q in self.manager.queues) != self._snap_ids:
            self._snap = None
            return
        for qid in set(queue_ids):
            idx = self._snap_by_id.get(qid)
            if idx is None:
                self._snap = None
                return
            qs, q = self._snap_entries[idx]
            qs.depth = len(q)
            qs.tokens = q.tok_sum
            qs.mean_len = q.mean_len
            self._snap_profiles[qid] = QueueProfile(
                index=qs.index, mean_len=q.mean_len,
                weights=weights_for_queue(self.manager.meta, q.mean_len))
            self._snap_coeffs[idx] = self._head_coeff(q)
        self._snap.waiting = sum(qs.depth for qs, _ in self._snap_entries)
        self._snap.waiting_tokens = sum(qs.tokens
                                        for qs, _ in self._snap_entries)
        self._snap_time = None           # heads may have changed → refresh

    def _rebuild_snapshot(self, now: float) -> None:
        profiles = self.manager.profiles()
        self._snap_profiles = profiles
        entries: list[tuple[QueueSnapshot, SchedulerQueue]] = []
        queues: list[QueueSnapshot] = []
        total_reqs = 0
        total_tokens = 0
        for i, q in enumerate(self.manager.queues):
            qs = QueueSnapshot(
                queue_id=q.queue_id, index=i,
                lo=q.bounds.lo, hi=q.bounds.hi,
                depth=len(q), tokens=q.tok_sum, mean_len=q.mean_len)
            entries.append((qs, q))
            queues.append(qs)
            total_reqs += len(q)
            total_tokens += q.tok_sum
        self._snap = SchedulerSnapshot(policy=self.name, waiting=total_reqs,
                                       waiting_tokens=total_tokens,
                                       queues=queues)
        self._snap_entries = entries
        self._snap_by_id = {q.queue_id: i for i, (_, q) in enumerate(entries)}
        self._snap_ids = tuple(q.queue_id for q in self.manager.queues)
        self._snap_coeffs = [self._head_coeff(q) for _, q in entries]
        self._snap_time = None

    def _refresh_heads(self, now: float) -> None:
        for (qs, _), coef in zip(self._snap_entries, self._snap_coeffs):
            if coef is None:
                qs.head_len, qs.head_wait, qs.head_score = None, 0.0, 0.0
            else:
                arr, blen, base, slope = coef
                wait = now - arr
                if wait < 0.0:
                    wait = 0.0
                qs.head_len = blen
                qs.head_wait = wait
                qs.head_score = base + slope * wait
        self._snap_time = now

    def snapshot_cached(self, now: float) -> SchedulerSnapshot:
        """Event-driven snapshot: identical values to ``snapshot(now)`` but
        O(queues) per access (head-score refresh) instead of O(waiting)
        (full aggregate rebuild) — rebuilt only after structural changes."""
        if self._snap is None:
            self._rebuild_snapshot(now)
        if self._snap_time != now:
            self._refresh_heads(now)
        return self._snap

    # ---- tactical loop (Algorithm 1) --------------------------------------

    def tick(self, now: float, budget: BatchBudget) -> BatchPlan:
        self.tick_count += 1
        profiles = self.manager.profiles()
        updated_scores: dict[int, float] = {}
        for q in self.manager.queues:
            if len(q):
                req = q.peek()
                updated_scores[q.queue_id] = compute_score(
                    req, profiles[q.queue_id], now, self.c_prefill)
        pruned = self.manager.prune_empty()
        if not updated_scores:
            if pruned:
                self._mark_snapshot_dirty()
            return BatchPlan(requests=[])
        primary_id = max(updated_scores, key=updated_scores.get)
        primary = next(q for q in self.manager.queues
                       if q.queue_id == primary_id)
        builder = BatchBuilder(budget)
        plan = builder.build(self.manager, primary, now)
        if pruned:
            self._mark_snapshot_dirty()
        elif plan.requests:
            self._snapshot_delta([r.queue_id for r in plan.requests
                                  if r.queue_id is not None])
        return plan

    # ---- strategic loop ----------------------------------------------------

    def maybe_reoptimize(self, now: float, force: bool = False) -> bool:
        """Run the strategic loop if its period elapsed.  Returns True when a
        new policy was installed."""
        acted = False
        if self.cfg.enable_meta_opt:
            self._advance_trial(now)
        # Bootstrap: the paper's offline mode installs a baseline policy
        # before live serving; a cold single-queue start re-partitions as
        # soon as min_history is available rather than waiting a period.
        if (len(self.manager.queues) == 1
                and len(self.monitor.history) >= self.cfg.min_history):
            force = True
        if force or now - self._last_reopt >= self.cfg.reopt_interval:
            lengths = self.monitor.historical_lengths()
            if len(lengths) >= self.cfg.min_history:
                self._repartition(lengths)
                self._last_reopt = now
                self.reopt_count += 1
                acted = True
        return acted

    def _current_meta(self) -> MetaParams:
        return self._trial_meta or self.manager.meta

    def _repartition(self, lengths: np.ndarray) -> None:
        meta = self._current_meta()
        if self.partitioner is not None:
            bounds = self.partitioner(lengths)
        else:
            pcfg = PartitionConfig(alpha_split=meta.alpha_split,
                                   max_queues=meta.max_queues)
            bounds = refine_and_prune(lengths, pcfg)
        self.manager.apply_policy(bounds, meta)
        self._mark_snapshot_dirty()

    def online_adjust(self, now: float) -> None:
        """Online (real-time) mode (§3.1): lightweight boundary nudges from
        the recent window instead of the full Refine-and-Prune — cheap
        statistical recentering of interior edges toward recent quantiles."""
        recent = self.monitor.recent_lengths()
        if len(recent) < 32 or len(self.manager.queues) < 2:
            return
        k = len(self.manager.queues)
        qs = np.quantile(recent, np.linspace(0, 1, k + 1)[1:-1])
        blend = self.cfg.online_blend
        for i, q in enumerate(self.manager.queues[:-1]):
            tgt = float(qs[i]) if i < len(qs) else q.bounds.hi
            if q.bounds.hi == float("inf"):
                continue
            new_hi = (1 - blend) * q.bounds.hi + blend * tgt
            nxt = self.manager.queues[i + 1]
            new_hi = min(max(new_hi, q.bounds.lo + 1.0),
                         nxt.bounds.hi - 1.0 if nxt.bounds.hi != float("inf")
                         else new_hi)
            q.bounds = QueueBounds(q.bounds.lo, new_hi)
            nxt.bounds = QueueBounds(new_hi, nxt.bounds.hi)
        self._mark_snapshot_dirty()

    # ---- fleet-level strategic plane (shared policy store) -----------------

    def export_observation(self, sample_cap: int = 2048) -> dict:
        """Strategic observation for the fleet policy store: a recent sample
        of the local length distribution (weighted upstream by the replica's
        true arrival count), the local Bayesian posterior, and the currently
        installed partition edges.  Read-only and cheap — safe to call from
        a periodic sync loop."""
        lengths = self.monitor.historical_lengths()
        if len(lengths) > sample_cap:
            lengths = lengths[-sample_cap:]
        return {
            "lengths": lengths,
            "n_arrivals": self.monitor.total_arrivals,
            "trials": self.meta_opt.export_trials(),
            "edges": [q.bounds.hi for q in self.manager.queues[:-1]],
            "max_queues": self.cfg.max_queues,
            # Output-length posterior (prediction plane), pooled fleet-wide
            # by the store; None when no predictor is wired or it has
            # nothing to share yet.
            "predictor": (self.predictor.export_state()
                          if self.predictor is not None else None),
        }

    def adopt_global_policy(self, boundaries, meta: MetaParams, trials=(),
                            local_weight: float = 0.0, now: float = 0.0,
                            epoch: int = 0) -> None:
        """Install a fleet-level policy with per-replica adaptation.

        ``local_weight`` w ∈ [0,1] sets how much locally learned structure
        survives: interior boundary edges become (1−w)·global + w·nearest
        local edge, and the scoring meta-vector blends the same way.  w=0 is
        a pure global install (warm start); w=1 keeps local structure and
        only absorbs the shared posterior.  Global trials are merged into
        the local Bayesian optimizer either way, so a replica's next trial
        starts from the pooled fleet posterior instead of random warmup."""
        w = min(max(float(local_weight), 0.0), 1.0)
        g_bounds = [QueueBounds(b.lo, b.hi) for b in boundaries]
        local_edges = [q.bounds.hi for q in self.manager.queues[:-1]
                       if q.bounds.hi != float("inf")]
        if w > 0.0 and local_edges and len(self.manager.queues) > 1:
            bounds = self._blend_boundaries(g_bounds, local_edges, w)
        else:
            bounds = g_bounds
        # Scoring dims blend; the *structural* knobs (queue budget, length
        # normalizer) stay per-replica — the global meta's as_vector() does
        # not carry them, so taking meta.max_queues/b_norm here would
        # silently overwrite the operator's local EWSJFConfig with the
        # store's defaults.  The blend target is the *installed* meta, not
        # _current_meta(): mid-trial that would be the optimizer's random
        # exploration candidate, and w would re-inject exploration noise
        # into the serving policy on every adoption.
        local_meta = self.manager.meta
        gv = np.asarray(meta.as_vector())
        if w > 0.0:
            lv = np.asarray(local_meta.as_vector())
            gv = (1.0 - w) * gv + w * lv
        blended = MetaParams.from_vector(gv,
                                         max_queues=self.cfg.max_queues,
                                         b_norm=local_meta.b_norm)
        if trials:
            self.meta_opt.merge_trials(trials)
        self.manager.apply_policy(bounds, blended)
        # The adopted policy supersedes any in-flight local trial's Θ; the
        # trial keeps running but must score the structure actually serving.
        if self._trial_meta is not None:
            self._trial_meta = blended
        self._mark_snapshot_dirty()
        # Deliberately NOT resetting _last_reopt: the local strategic loop
        # keeps its own cadence (with sync_interval < reopt_interval a reset
        # here would postpone local repartitioning forever).  The store
        # re-broadcasts after a local repartition via reopt_count below.
        self.adopted_epoch = epoch
        self._reopt_at_adopt = self.reopt_count

    @staticmethod
    def _blend_boundaries(g_bounds: list[QueueBounds],
                          local_edges: list[float],
                          w: float) -> list[QueueBounds]:
        """Keep the *global* queue count; pull each global interior edge
        toward the nearest locally learned edge by ``w``.  Edges that would
        collapse an interval (non-monotonic after blending) are dropped."""
        g_edges = [b.hi for b in g_bounds[:-1] if b.hi != float("inf")]
        le = np.asarray(local_edges, dtype=np.float64)
        blended: list[float] = []
        for g in g_edges:
            nearest = float(le[np.argmin(np.abs(le - g))])
            e = (1.0 - w) * g + w * nearest
            if not blended or e > blended[-1]:
                blended.append(e)
        edges = [0.0] + blended + [float("inf")]
        return [QueueBounds(edges[i], edges[i + 1])
                for i in range(len(edges) - 1)]

    def warm_start_from(self, boundaries, meta: MetaParams, trials=(),
                        now: float = 0.0, epoch: int = 0) -> None:
        """Cold-start path for freshly scaled-up replicas: install the
        current global policy verbatim (no local structure exists to blend)
        and seed the Bayesian posterior, so the first request already sees
        the fleet's learned queue structure instead of a single [0, ∞)
        queue."""
        self.adopt_global_policy(boundaries, meta, trials=trials,
                                 local_weight=0.0, now=now, epoch=epoch)

    def _advance_trial(self, now: float) -> None:
        if self._trial_meta is None:
            self._trial_meta = self.meta_opt.suggest()
            self._trial_start = now
            self._trial_finish_mark = self.monitor.total_finished
            self._trial_token_mark = self.monitor.total_tokens_out
            return
        if now - self._trial_start < self.cfg.trial_interval:
            return
        # Close the trial: compute reward over the trial window.
        elapsed = max(now - self._trial_start, 1e-9)
        stats = self.monitor.window_stats(elapsed)
        qlens = [np.asarray([r.work_len for r in q.requests],
                            dtype=np.float64)
                 for q in self.manager.queues]
        terms = reward_terms(qlens, stats, len(self.manager.queues))
        tokens = self.monitor.total_tokens_out - self._trial_token_mark
        thr_bonus = tokens / elapsed / 1000.0
        r = reward(terms, self.cfg.reward_weights, throughput_bonus=thr_bonus)
        self.meta_opt.observe(self._trial_meta, r)
        nxt = self.meta_opt.suggest()
        self._trial_meta = nxt
        self._trial_start = now
        self._trial_finish_mark = self.monitor.total_finished
        self._trial_token_mark = self.monitor.total_tokens_out

    # ---- checkpointing -----------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "meta": self._current_meta().__dict__,
            "bounds": [(q.bounds.lo, q.bounds.hi, q.is_bubble)
                       for q in self.manager.queues],
            "history": list(self.monitor.history)[-10_000:],
            "trials": [(t.theta.tolist(), t.reward)
                       for t in self.meta_opt.trials],
            "waiting": [
                {"prompt_len": r.prompt_len, "arrival_time": r.arrival_time,
                 "max_new_tokens": r.max_new_tokens, "request_id": r.request_id}
                for q in self.manager.queues for r in q.requests],
        }

    def load_state_dict(self, state: dict) -> None:
        meta = MetaParams(**state["meta"])
        bounds = [QueueBounds(lo, hi) for lo, hi, _ in state["bounds"]]
        self.manager.apply_policy(bounds, meta)
        self._mark_snapshot_dirty()
        for i, (_, _, is_bubble) in enumerate(state["bounds"]):
            self.manager.queues[i].is_bubble = is_bubble
        self.monitor.history.extend(state["history"])
        import numpy as _np
        from .meta_optimizer import Trial
        self.meta_opt.trials = [Trial(_np.asarray(t), r)
                                for t, r in state["trials"]]
        for spec in state["waiting"]:
            req = Request(prompt_len=spec["prompt_len"],
                          arrival_time=spec["arrival_time"],
                          max_new_tokens=spec["max_new_tokens"])
            # interval-only routing: the restored bounds already include any
            # bubbles that existed at save time.
            self.monitor.observe_arrival(req)
            self.manager.route(req, allow_bubble=False)


def make_scheduler(name: str, **kw) -> BaseScheduler:
    registry = {
        "fcfs": FCFSScheduler,
        "sjf": SJFScheduler,
        "static_priority": StaticPriorityScheduler,
        "ewsjf": EWSJFScheduler,
    }
    if name not in registry:
        raise ValueError(f"unknown scheduler '{name}'; have {sorted(registry)}")
    return registry[name](**kw)
