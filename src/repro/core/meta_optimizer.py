"""Bandit-based Bayesian meta-optimizer (§4.4.2).

Continuous policy search over the meta-parameters

    Θ = {a_urg, b_urg, a_fair, b_fair, a_base, b_base, α_split}

maximizing the multi-objective reward R(Θ) (Eq. 5, core/monitor.py).  The
paper motivates Bayesian optimization because the scheduling landscape is
non-convex and discontinuous; convergence is observed within 5–8 trials
(App. B) — our benchmark reproduces that (benchmarks/bench_meta_optimizer).

Implementation: Gaussian-process surrogate (RBF kernel, unit signal prior,
estimated noise) + Expected Improvement acquisition maximized over a
quasi-random candidate sweep.  Pure numpy/scipy — the optimizer runs on the
host in the *strategic* (background) loop, never on the accelerator path.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
from scipy.linalg import cho_factor, cho_solve
from scipy.special import erf

from .types import MetaParams

# Search box for Θ (scaled units; see MetaParams docstring).
DEFAULT_BOUNDS = np.array([
    (-2.0, 2.0),    # a_urg
    (0.05, 4.0),    # b_urg   (>0 keeps Thm A.1 starvation freedom)
    (-2.0, 2.0),    # a_fair
    (0.0, 3.0),     # b_fair
    (-1.0, 1.0),    # a_base
    (0.0, 3.0),     # b_base
    (1.2, 8.0),     # alpha_split  (α > 1 per Eq. 2)
])


def _norm_pdf(z):
    return np.exp(-0.5 * z * z) / np.sqrt(2 * np.pi)


def _norm_cdf(z):
    return 0.5 * (1.0 + erf(z / np.sqrt(2.0)))


class GaussianProcess:
    """Minimal GP regressor with RBF kernel for low-dim BO."""

    def __init__(self, length_scale: float = 0.35, signal: float = 1.0,
                 noise: float = 1e-3):
        self.ls = length_scale
        self.signal = signal
        self.noise = noise
        self.X: np.ndarray | None = None
        self.y_mean = 0.0
        self.y_std = 1.0
        self._alpha = None
        self._cho = None

    def _k(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return self.signal * np.exp(-0.5 * d2 / (self.ls ** 2))

    def fit(self, X: np.ndarray, y: np.ndarray) -> None:
        self.X = np.atleast_2d(X)
        y = np.asarray(y, dtype=np.float64)
        self.y_mean = float(y.mean())
        self.y_std = float(y.std()) or 1.0
        yn = (y - self.y_mean) / self.y_std
        K = self._k(self.X, self.X) + self.noise * np.eye(len(yn))
        self._cho = cho_factor(K, lower=True)
        self._alpha = cho_solve(self._cho, yn)

    def predict(self, Xs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        Ks = self._k(np.atleast_2d(Xs), self.X)
        mu = Ks @ self._alpha
        v = cho_solve(self._cho, Ks.T)
        var = np.maximum(self.signal - np.einsum("ij,ji->i", Ks, v), 1e-12)
        return mu * self.y_std + self.y_mean, np.sqrt(var) * self.y_std


@dataclass
class Trial:
    theta: np.ndarray
    reward: float


def _trial_key(theta) -> tuple:
    """Dedup identity for a trial point: Θ rounded to 6 decimals (scaled
    units) — the one definition shared by local optimizers and the fleet
    policy store, so both sides agree on which trials are 'the same'."""
    return tuple(np.round(np.asarray(theta, dtype=np.float64), 6))


def pool_trials(existing, new, cap: int) -> list[tuple[list[float], float]]:
    """Merge (Θ, reward) observation lists: first-seen wins on duplicate Θ,
    and over ``cap`` total the lowest-reward entries are dropped (relative
    order otherwise preserved).  Serializable-tuple domain — used by the
    fleet policy store and ``BayesianMetaOptimizer.merge_trials``."""
    out = [(list(t), float(r)) for t, r in existing]
    seen = {_trial_key(t) for t, _ in out}
    for theta, r in new:
        key = _trial_key(theta)
        if key in seen:
            continue
        seen.add(key)
        out.append((list(theta), float(r)))
    if len(out) > cap:
        keep = sorted(range(len(out)), key=lambda i: out[i][1],
                      reverse=True)[:cap]
        out = [out[i] for i in sorted(keep)]
    return out


@dataclass
class BayesianMetaOptimizer:
    """Suggest → observe loop.  ``suggest()`` returns the next Θ to try;
    ``observe(theta, reward)`` updates the posterior."""

    bounds: np.ndarray = field(default_factory=lambda: DEFAULT_BOUNDS.copy())
    n_init: int = 4                  # random (Sobol-ish) warmup trials
    candidates: int = 512            # acquisition sweep size
    xi: float = 0.01                 # EI exploration margin
    seed: int = 0
    max_queues: int = 32

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        self.trials: list[Trial] = []
        self.gp = GaussianProcess()

    # ---- unit-cube <-> Θ ------------------------------------------------

    def _to_unit(self, theta: np.ndarray) -> np.ndarray:
        lo, hi = self.bounds[:, 0], self.bounds[:, 1]
        return (theta - lo) / (hi - lo)

    def _from_unit(self, u: np.ndarray) -> np.ndarray:
        lo, hi = self.bounds[:, 0], self.bounds[:, 1]
        return lo + u * (hi - lo)

    # ---- public API -------------------------------------------------------

    def suggest(self) -> MetaParams:
        d = len(self.bounds)
        if len(self.trials) == 0:
            # Start from the hand-tuned default — anchors the search where a
            # human operator would start (the paper's baseline policy).
            return MetaParams(max_queues=self.max_queues)
        if len(self.trials) < self.n_init:
            u = self.rng.random(d)
            return MetaParams.from_vector(self._from_unit(u),
                                          max_queues=self.max_queues)
        X = np.stack([self._to_unit(t.theta) for t in self.trials])
        y = np.asarray([t.reward for t in self.trials])
        self.gp.fit(X, y)
        best = y.max()
        U = self.rng.random((self.candidates, d))
        mu, sd = self.gp.predict(U)
        z = (mu - best - self.xi) / sd
        ei = (mu - best - self.xi) * _norm_cdf(z) + sd * _norm_pdf(z)
        u_star = U[int(np.argmax(ei))]
        return MetaParams.from_vector(self._from_unit(u_star),
                                      max_queues=self.max_queues)

    def observe(self, meta: MetaParams, reward: float) -> None:
        self.trials.append(Trial(np.asarray(meta.as_vector(), dtype=np.float64),
                                 float(reward)))

    # ---- fleet-level posterior sharing ------------------------------------

    def export_trials(self) -> list[tuple[list[float], float]]:
        """Serializable posterior: every (Θ, reward) observation so far.
        Consumed by the fleet policy store, which pools trials across
        replicas into one shared surrogate."""
        return [(t.theta.tolist(), float(t.reward)) for t in self.trials]

    def merge_trials(self, trials, cap: int = 256) -> int:
        """Fold externally observed (Θ, reward) pairs — e.g. the fleet
        store's pooled posterior — into this optimizer's trial history via
        the shared ``pool_trials`` semantics (first-seen dedup, lowest-
        reward capped, order otherwise preserved so ``converged`` keeps its
        recency semantics).  Returns the number of trials added."""
        before = {_trial_key(t.theta) for t in self.trials}
        pooled = pool_trials(self.export_trials(), trials, cap)
        self.trials = [Trial(np.asarray(t, dtype=np.float64), r)
                       for t, r in pooled]
        return sum(1 for t, _ in pooled if _trial_key(t) not in before)

    @property
    def best(self) -> MetaParams | None:
        if not self.trials:
            return None
        t = max(self.trials, key=lambda t: t.reward)
        return MetaParams.from_vector(t.theta, max_queues=self.max_queues)

    @property
    def best_reward(self) -> float:
        return max((t.reward for t in self.trials), default=-np.inf)

    def converged(self, window: int = 3, tol: float = 0.02) -> bool:
        """Paper App. B: reward stabilizes after 5–8 trials; we declare
        convergence when the best reward improved < tol over the last
        ``window`` trials."""
        if len(self.trials) < self.n_init + window:
            return False
        rewards = [t.reward for t in self.trials]
        prev_best = max(rewards[:-window])
        return self.best_reward - prev_best < tol * max(abs(prev_best), 1e-9)
