"""Queue manager: dynamic routing, on-demand bubble queues, pruning.

Implements the Dispatcher of the tactical loop (§3.2) and Algorithm 2
(On-Demand Bubble Queue Creation, §4.3 / App. D):

    1:  Q_i, Q_{i+1} ← FindAdjacentQueues(L, Q)
    3:  if L ≤ Q_i.max_len × 1.10:            assign to Q_i
    5:  elif L ≥ Q_{i+1}.min_len × 0.90:      assign to Q_{i+1}
    7:  else:  true gap — create a bubble queue centered on L, width
        min(default_bubble_width, available), clipped to neighbours.

Queues are kept in ascending order of their interval; indices are re-derived
after structural changes, so the scoring queue-factor q_i always reflects the
current ordering.  Empty-queue pruning (Alg. 1 lines 8–13) removes queues
whose empty-streak exceeds ``empty_threshold`` — but never *policy* queues
(those from the strategic partition), only bubbles, unless
``prune_policy_queues`` is set (the strategic loop owns policy structure).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from .scoring import QueueProfile, weights_for_queue
from .types import MetaParams, QueueBounds, Request


@dataclass
class SchedulerQueue:
    """A single FIFO prompt-length queue."""

    bounds: QueueBounds
    queue_id: int
    is_bubble: bool = False
    requests: deque = field(default_factory=deque)
    empty_cnt: int = 0
    routed_count: int = 0
    routed_len_sum: float = 0.0
    tok_sum: int = 0                  # waiting prompt tokens (incremental)
    obs_min: float = float("inf")     # observed data edges (Alg. 2's
    obs_max: float = float("-inf")    # Q_i.max_len / Q_{i+1}.min_len)

    def __len__(self) -> int:
        return len(self.requests)

    def peek(self) -> Optional[Request]:
        return self.requests[0] if self.requests else None

    def push(self, req: Request) -> None:
        # All queue statistics run on the *work* length (uncached suffix +
        # predicted decode work) — identical to prompt_len when cached_len
        # is 0 and no prediction is stamped.  Stamps are set at ingest and
        # never mutated while queued, so push/pop stay balanced.
        L = req.work_len
        self.requests.append(req)
        self.routed_count += 1
        self.routed_len_sum += L
        self.tok_sum += int(L)
        self.obs_min = min(self.obs_min, L)
        self.obs_max = max(self.obs_max, L)
        self.empty_cnt = 0

    def pop(self) -> Request:
        req = self.requests.popleft()
        self.tok_sum -= int(req.work_len)
        return req

    def clear_requests(self) -> list[Request]:
        out = list(self.requests)
        self.requests.clear()
        self.tok_sum = 0
        return out

    @property
    def mean_len(self) -> float:
        """b̄_q — mean prompt length of everything ever routed here; falls
        back to the interval center for fresh queues."""
        if self.routed_count:
            return self.routed_len_sum / self.routed_count
        c = self.bounds.center
        return c if c != float("inf") else self.bounds.lo


@dataclass
class BubbleConfig:
    default_bubble_width: float = 256.0
    lower_tolerance: float = 1.10      # Alg. 2 line 3
    upper_tolerance: float = 0.90      # Alg. 2 line 5


class QueueManager:
    """Owns the live queue set; applies policies from the strategic loop and
    routes requests on the tactical path."""

    def __init__(self, boundaries: list[QueueBounds], meta: MetaParams,
                 bubble: BubbleConfig | None = None,
                 empty_threshold: int = 50):
        self.bubble_cfg = bubble or BubbleConfig()
        self.empty_threshold = empty_threshold
        self.meta = meta
        self._next_id = 0
        self.queues: list[SchedulerQueue] = []
        self.bubbles_created = 0
        self.apply_policy(boundaries, meta)

    # ---- strategic-loop interface --------------------------------------

    def apply_policy(self, boundaries: list[QueueBounds], meta: MetaParams) -> None:
        """Install a new queue structure, re-routing any waiting requests.

        Called by the strategic loop (infrequent).  Waiting requests keep
        their arrival times, so no work is lost across policy swaps."""
        pending: list[Request] = []
        for q in self.queues:
            pending.extend(q.requests)
        self.meta = meta
        self.queues = []
        for b in sorted(boundaries, key=lambda x: x.lo):
            self.queues.append(SchedulerQueue(bounds=b, queue_id=self._alloc_id()))
        for r in sorted(pending, key=lambda r: r.arrival_time):
            self.route(r)

    def _alloc_id(self) -> int:
        self._next_id += 1
        return self._next_id - 1

    # ---- tactical-loop interface ---------------------------------------

    def route(self, req: Request, allow_bubble: bool = True) -> SchedulerQueue:
        """Dispatcher (Algorithm 2) against *observed* data edges:

        1. a queue whose observed range [obs_min, obs_max] (with the ±10%
           tolerance bands of lines 3/5) covers L takes the request;
        2. otherwise L sits in a true gap between the nearest observed data
           below and above → bubble queue (lines 8–14), carved out of the
           containing interval;
        3. with no observed data on one side (cold start / new extreme),
           fall back to interval routing — there is no meaningful gap yet.

        Routing runs on the request's *work* length: a long prompt with a
        hot cached prefix joins the queue of the short job it actually is
        (KV plane), and a short prompt predicted to decode long joins the
        queue of the long job it actually is (prediction plane); identical
        to prompt_len when neither plane has stamped the request.
        """
        L = req.work_len
        qi = self._find_interval(L)
        q = self.queues[qi]
        c = self.bubble_cfg

        def assign(target: SchedulerQueue) -> SchedulerQueue:
            target.push(req)
            req.queue_id = target.queue_id
            return target

        if not allow_bubble or q.routed_count == 0:
            return assign(q)
        # Line 3/5 tolerance test against the containing interval's own data
        # and its observed neighbours.
        below = max((x.obs_max for x in self.queues
                     if x.routed_count and x.obs_max <= L), default=None)
        above = min((x.obs_min for x in self.queues
                     if x.routed_count and x.obs_min >= L), default=None)
        if q.obs_min <= L <= q.obs_max:
            return assign(q)                      # inside observed mass
        if below is not None and L <= below * c.lower_tolerance:
            return assign(q if q.bounds.contains(below) else
                          self._queue_with_obs(below))
        if above is not None and L >= above * c.upper_tolerance:
            return assign(q if q.bounds.contains(above) else
                          self._queue_with_obs(above))
        if below is None or above is None:
            return assign(q)                      # one-sided: no gap defined
        # True gap: create a bubble queue (Alg. 2 lines 8–14).
        bubble = self._create_bubble(L, qi, below, above)
        return assign(bubble)

    def _queue_with_obs(self, value: float) -> SchedulerQueue:
        for x in self.queues:
            if x.routed_count and x.obs_min <= value <= x.obs_max:
                return x
        return self.queues[self._find_interval(value)]

    def _find_interval(self, L: float) -> int:
        for i, q in enumerate(self.queues):
            if q.bounds.lo <= L < q.bounds.hi or (
                    q.bounds.hi == float("inf") and L >= q.bounds.lo):
                return i
        return len(self.queues) - 1      # beyond range → last queue

    def _create_bubble(self, L: float, qi: int, below: float,
                       above: float) -> SchedulerQueue:
        """Algorithm 2 lines 8–14: split the containing interval around L,
        clipped to the observed neighbour edges (below, above)."""
        q = self.queues[qi]
        left_hi = max(below, q.bounds.lo)
        right_lo = min(above, q.bounds.hi)
        available = max(right_lo - left_hi, 1.0)
        rng = min(self.bubble_cfg.default_bubble_width, available)
        new_min = max(L - rng / 2.0, left_hi)
        new_max = min(L + rng / 2.0, right_lo)
        if new_max <= new_min:
            new_min, new_max = L - 0.5, L + 0.5
        # Carve the bubble interval out of the containing queue so the
        # partition stays contiguous and non-overlapping.
        bubble = SchedulerQueue(
            bounds=QueueBounds(new_min, new_max),
            queue_id=self._alloc_id(), is_bubble=True)
        old = q.bounds
        q.bounds = QueueBounds(old.lo, new_min)
        tail = SchedulerQueue(bounds=QueueBounds(new_max, old.hi),
                              queue_id=self._alloc_id(), is_bubble=q.is_bubble)
        # Move any waiting requests that now belong to the new intervals.
        stay, move_b, move_t = deque(), [], []
        for r in q.requests:
            if bubble.bounds.contains(r.work_len):
                move_b.append(r)
            elif tail.bounds.contains(r.work_len):
                move_t.append(r)
            else:
                stay.append(r)
        q.requests = stay
        # recompute q's observed edges (its requests may have moved)
        q.obs_min, q.obs_max = float("inf"), float("-inf")
        q.routed_count, q.routed_len_sum, q.tok_sum = 0, 0.0, 0
        for r in stay:
            L = r.work_len
            q.obs_min = min(q.obs_min, L)
            q.obs_max = max(q.obs_max, L)
            q.routed_count += 1
            q.routed_len_sum += L
            q.tok_sum += int(L)
        # re-label moved requests: queue_id drives delta publication
        # (scheduler._snapshot_delta) and must name the queue that now
        # actually holds the request
        for r in move_b:
            bubble.push(r)
            r.queue_id = bubble.queue_id
        for r in move_t:
            tail.push(r)
            r.queue_id = tail.queue_id
        self.queues[qi + 1: qi + 1] = [bubble, tail]
        self.bubbles_created += 1
        return bubble

    def prune_empty(self) -> list[int]:
        """Alg. 1 lines 8–13: advance empty counters, drop expired bubbles.
        Returns removed queue ids."""
        removed = []
        keep = []
        for q in self.queues:
            if len(q) == 0:
                q.empty_cnt += 1
                if q.is_bubble and q.empty_cnt > self.empty_threshold:
                    removed.append(q.queue_id)
                    continue
            keep.append(q)
        if removed:
            # Re-absorb the removed bubbles' intervals into left neighbours.
            self.queues = keep
            self._heal_intervals()
        return removed

    def _heal_intervals(self) -> None:
        for a, b in zip(self.queues[:-1], self.queues[1:]):
            if a.bounds.hi != b.bounds.lo:
                a.bounds = QueueBounds(a.bounds.lo, b.bounds.lo)
        if self.queues:
            first = self.queues[0]
            if first.bounds.lo != 0.0:
                first.bounds = QueueBounds(0.0, first.bounds.hi)
            last = self.queues[-1]
            if last.bounds.hi != float("inf"):
                last.bounds = QueueBounds(last.bounds.lo, float("inf"))

    # ---- scoring support -------------------------------------------------

    def profiles(self) -> dict[int, QueueProfile]:
        """Per-queue profiles with context-aware weights (index = ascending
        position, so qf follows the paper's queue-index convention)."""
        out = {}
        for i, q in enumerate(self.queues):
            out[q.queue_id] = QueueProfile(
                index=i, mean_len=q.mean_len,
                weights=weights_for_queue(self.meta, q.mean_len))
        return out

    def non_empty(self) -> list[SchedulerQueue]:
        return [q for q in self.queues if len(q)]

    def waiting_count(self) -> int:
        return sum(len(q) for q in self.queues)

    def adjacent_of(self, queue_id: int) -> list[SchedulerQueue]:
        """GetAdjacent(q) for backfill — nearest neighbours first."""
        idx = next((i for i, q in enumerate(self.queues)
                    if q.queue_id == queue_id), None)
        if idx is None:
            return []
        order: list[SchedulerQueue] = []
        lo, hi = idx - 1, idx + 1
        while lo >= 0 or hi < len(self.queues):
            if lo >= 0:
                order.append(self.queues[lo]); lo -= 1
            if hi < len(self.queues):
                order.append(self.queues[hi]); hi += 1
        return order
