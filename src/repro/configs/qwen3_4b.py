"""qwen3-4b — qk_norm, GQA, head_dim=128 [hf:Qwen/Qwen3-8B; hf]."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="qwen3-4b", family="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=9728, vocab_size=151936, head_dim=128,
        qk_norm=True, attn_kind="full", rope_theta=1_000_000.0,
    ),
    smoke=ModelConfig(
        name="qwen3-4b-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16, qk_norm=True,
    ),
)
