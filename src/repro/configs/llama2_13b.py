"""llama2-13b-chat — the paper's own evaluation model (§6.2)."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="llama2-13b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=13824, vocab_size=32000, head_dim=128,
        attn_kind="full", rope_theta=10000.0, max_seq_len=4096,
    ),
    smoke=ModelConfig(
        name="llama2-13b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
    ),
)
