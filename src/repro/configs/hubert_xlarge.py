"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447;
unverified].  Modality frontend is a stub: input_specs() provides
precomputed frame embeddings (B, S, d_model)."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="hubert-xlarge", family="encoder",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab_size=504, head_dim=80,
        causal=False, is_encoder_only=True, input_mode="embeddings",
    ),
    smoke=ModelConfig(
        name="hubert-xlarge-smoke", family="encoder",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=32, head_dim=16,
        causal=False, is_encoder_only=True, input_mode="embeddings",
    ),
)
