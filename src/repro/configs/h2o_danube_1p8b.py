"""h2o-danube-1.8b — llama+mistral mix, sliding-window attention
[arXiv:2401.16818; hf]."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=6912, vocab_size=32000, head_dim=80,
        attn_kind="swa", window=4096, rope_theta=10000.0,
        subquadratic=True,       # SWA: decode memory bounded by the window
        max_seq_len=524_288,
    ),
    smoke=ModelConfig(
        name="h2o-danube-1.8b-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        attn_kind="swa", window=32, subquadratic=True,
    ),
)
