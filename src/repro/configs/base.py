"""ModelConfig — one schema covering all ten assigned architecture families.

Every assigned architecture (DESIGN.md §5) is expressed as an instance of
this dataclass; the model assembly (models/model.py) reads only this config.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // n_heads

    # ---- attention variants ----
    attn_kind: str = "full"          # full | swa | local_global
    window: int = 4096               # SWA / local window
    local_global_period: int = 0     # gemma3: 6 (5 local + 1 global)
    qk_norm: bool = False            # qwen3
    rope_theta: float = 10000.0
    causal: bool = True              # False for encoder-only

    # ---- MLA (deepseek-v2 / minicpm3) ----
    use_mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0              # 0 → head_dim

    # ---- MoE ----
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 2
    moe_d_ff: int = 0                # per-expert hidden (0 → d_ff)
    capacity_factor: float = 1.25
    first_dense_layers: int = 0      # deepseek: first layer is dense MLP

    # ---- SSM (mamba2) ----
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0                 # 0 → 2 * d_model
    ssm_chunk: int = 256
    conv_width: int = 4
    ssm_groups: int = 1

    # ---- hybrid layer pattern (recurrentgemma) ----
    # Pattern of per-layer kinds within one period; empty → homogeneous.
    # Kinds: "attn", "rglru", "ssm", "moe", "local", "global"
    pattern: tuple = ()
    rnn_width: int = 0               # RG-LRU width (0 → d_model)

    # ---- I/O mode ----
    input_mode: str = "tokens"       # tokens | embeddings (vlm/audio stubs)
    is_encoder_only: bool = False
    tie_embeddings: bool = False

    # ---- capability flags for the dry-run matrix ----
    subquadratic: bool = False       # eligible for long_500k
    max_seq_len: int = 131072

    # ---- numerics ----
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0       # gemma-style final softcap (0 = off)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.d_inner == 0 and self.family == "ssm":
            object.__setattr__(self, "d_inner", 2 * self.d_model)
        if self.moe_d_ff == 0 and self.n_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)
        if not self.pattern:
            kind = {"ssm": "ssm"}.get(self.family, "attn")
            if self.family == "moe":
                kind = "attn"        # attn + moe mlp handled per-layer
            object.__setattr__(self, "pattern", (kind,))

    # ---- derived sizes ----

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def kv_cache_dims_per_token(self) -> int:
        """Per-layer, per-token KV cache width (elements)."""
        if self.use_mla:
            return self.kv_lora_rank + self.rope_head_dim   # latent cache
        return 2 * self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (dense estimate; used for rooflines)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd, H, K = self.head_dim, self.n_heads, self.n_kv_heads
        n = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        kinds = [self.pattern[i % len(self.pattern)] for i in range(self.n_layers)]
        for i, kind in enumerate(kinds):
            if kind in ("attn", "local", "global"):
                if self.use_mla:
                    r = self.kv_lora_rank
                    per = (d * H * hd                      # q
                           + d * (r + self.rope_head_dim)  # kv down
                           + r * H * (hd + self.v_head_dim)  # kv up
                           + H * self.v_head_dim * d)      # o
                else:
                    per = d * H * hd + 2 * d * K * hd + H * hd * d
            elif kind == "rglru":
                w = self.rnn_width
                per = 2 * d * w + w * d + 3 * w
            elif kind == "ssm":
                di, N = self.d_inner, self.ssm_state
                per = d * (2 * di + 2 * self.ssm_groups * N + self.n_ssm_heads) + di * d
            else:
                per = 0
            n += per
            # MLP
            if self.n_experts and i >= self.first_dense_layers and kind != "ssm":
                e_ff = self.moe_d_ff
                n += (self.n_experts + self.n_shared_experts) * 3 * d * e_ff
                n += d * self.n_experts               # router
            elif kind == "ssm":
                pass                                   # mamba blocks have no MLP
            else:
                mult = 2 if self.is_encoder_only else 3   # GeLU vs SwiGLU
                n += mult * d * ff
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k slice) — used for MODEL_FLOPS."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        moe_layers = max(self.n_layers - self.first_dense_layers, 0)
        all_experts = moe_layers * self.n_experts * 3 * d * self.moe_d_ff
        active = moe_layers * self.moe_top_k * 3 * d * self.moe_d_ff
        return full - all_experts + active

    def scaled(self, **overrides) -> "ModelConfig":
        return replace(self, **overrides)
