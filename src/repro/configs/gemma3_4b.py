"""gemma3-4b — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified].  head_dim=256 (gemma3 convention)."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
        d_ff=10240, vocab_size=262144, head_dim=256,
        attn_kind="local_global", local_global_period=6, window=1024,
        pattern=("local", "local", "local", "local", "local", "global"),
        rope_theta=1_000_000.0, tie_embeddings=True,
        subquadratic=True,   # 5/6 layers bounded-window; global layers use
                             # the seq-sharded flash-decode path (DESIGN §5)
        max_seq_len=524_288,
    ),
    smoke=ModelConfig(
        name="gemma3-4b-smoke", family="dense",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        attn_kind="local_global", local_global_period=6, window=16,
        pattern=("local", "local", "local", "local", "local", "global"),
        tie_embeddings=True, subquadratic=True,
    ),
)
