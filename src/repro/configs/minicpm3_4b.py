"""minicpm3-4b — MLA attention [hf:openbmb/MiniCPM3-4B; hf]."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=6400, vocab_size=73448, head_dim=64,
        use_mla=True, kv_lora_rank=256, rope_head_dim=32,
        attn_kind="full", rope_theta=10000.0,
    ),
    smoke=ModelConfig(
        name="minicpm3-4b-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        use_mla=True, kv_lora_rank=32, rope_head_dim=8,
    ),
)
