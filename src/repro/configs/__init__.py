"""Config registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``.

One module per assigned architecture (exact public-literature configs), plus
the paper's own eval model (llama2-13b).  Smoke configs are reduced same-
family variants for CPU tests; full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

from .base import ModelConfig

_REGISTRY: dict = {}
_SMOKE: dict = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_loaded = False


def _ensure_loaded():
    global _loaded
    if _loaded:
        return
    from . import (deepseek_v2_lite_16b, gemma3_4b, h2o_danube_1p8b,
                   hubert_xlarge, internvl2_76b, llama2_13b, mamba2_370m,
                   minicpm3_4b, phi35_moe_42b, qwen3_4b, recurrentgemma_9b)
    # imported for their registration side effect only
    _ = (deepseek_v2_lite_16b, gemma3_4b, h2o_danube_1p8b, hubert_xlarge,
         internvl2_76b, llama2_13b, mamba2_370m, minicpm3_4b, phi35_moe_42b,
         qwen3_4b, recurrentgemma_9b)
    _loaded = True
