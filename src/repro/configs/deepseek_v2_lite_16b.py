"""deepseek-v2-lite-16b — MLA kv_lora=512, shared+routed MoE top-6
[arXiv:2405.04434; hf].  Assignment line reads "2 shared+160 routed";
DeepSeek-V2-Lite itself has 64 routed experts (the 160 belongs to full
V2) — we follow the 64e top-6 + 2 shared reading, noted in DESIGN.md §8."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab_size=102400, head_dim=128,
        use_mla=True, kv_lora_rank=512, rope_head_dim=64,
        n_experts=64, n_shared_experts=2, moe_top_k=6, moe_d_ff=1408,
        first_dense_layers=1,
        attn_kind="full", rope_theta=10000.0,
    ),
    smoke=ModelConfig(
        name="deepseek-v2-lite-16b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        use_mla=True, kv_lora_rank=32, rope_head_dim=8,
        n_experts=8, n_shared_experts=1, moe_top_k=2, moe_d_ff=48,
        first_dense_layers=1,
    ),
)
