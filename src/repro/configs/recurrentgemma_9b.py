"""recurrentgemma-9b — RG-LRU + local attention, 2:1 recurrent:attention
(Griffin pattern: rglru, rglru, local-attn) [arXiv:2402.19427; unverified].
MQA (kv=1), head_dim=256, local window 2048."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab_size=256000, head_dim=256,
        pattern=("rglru", "rglru", "local"), window=2048,
        rnn_width=4096, tie_embeddings=True,
        subquadratic=True, max_seq_len=1_048_576,
        rope_theta=10000.0,
    ),
    smoke=ModelConfig(
        name="recurrentgemma-9b-smoke", family="hybrid",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=256, head_dim=16,
        pattern=("rglru", "rglru", "local"), window=16,
        rnn_width=64, tie_embeddings=True, subquadratic=True,
    ),
)
