"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, d_inner=2048, ssm_chunk=256,
        pattern=("ssm",), subquadratic=True, tie_embeddings=True,
        max_seq_len=1_048_576,
    ),
    smoke=ModelConfig(
        name="mamba2-370m-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, d_inner=128, ssm_chunk=32,
        pattern=("ssm",), subquadratic=True, tie_embeddings=True,
    ),
)
