"""internvl2-76b — InternViT + InternLM2 VLM backbone [arXiv:2404.16821;
unverified].  The ViT frontend is a stub: train/prefill consume precomputed
patch embeddings (B, S, d_model); decode generates text tokens."""
from . import register
from .base import ModelConfig

CONFIG = register(
    ModelConfig(
        name="internvl2-76b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab_size=128256, head_dim=128,
        attn_kind="full", rope_theta=1_000_000.0,
        input_mode="embeddings",
    ),
    smoke=ModelConfig(
        name="internvl2-76b-smoke", family="vlm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        input_mode="embeddings",
    ),
)
