"""Markdown link checker for the docs layer (no dependencies).

Scans the given markdown files for ``[text](target)`` links and verifies
that every *relative* target resolves to a file or directory on disk
(``#anchor`` fragments are checked against the target file's headings
using GitHub's slug rules — lowercase, spaces to dashes, punctuation
stripped).  External links (``http(s)://``, ``mailto:``) are skipped:
checking them would make CI flaky on network weather, and the job's
purpose is to keep the *internal* docs graph from rotting.

    python tools/check_links.py README.md ROADMAP.md docs/*.md

Exits 1 listing every broken link, 0 when the docs graph is intact.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — but not images' size suffixes or in-code backticks;
# nested ``[![badge](img)](url)`` resolves outer-first, which is fine
# because both targets get extracted by the finditer pass.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code/links, lowercase,
    drop punctuation, spaces to dashes."""
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)   # [t](u) -> t
    text = re.sub(r"[`*_]", "", text).strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def headings_of(path: Path) -> set[str]:
    try:
        content = path.read_text(encoding="utf-8")
    except OSError:
        return set()
    slugs = set()
    fence = False
    for line in content.splitlines():
        if line.lstrip().startswith("```"):
            fence = not fence
            continue
        if not fence:
            m = re.match(r"^#{1,6}\s+(.*)$", line)
            if m:
                slugs.add(github_slug(m.group(1)))
    return slugs


def check_file(md: Path, repo_root: Path) -> list[str]:
    errors: list[str] = []
    content = md.read_text(encoding="utf-8")
    # strip fenced code blocks: ASCII diagrams and shell examples are full
    # of "[x](y)"-shaped noise that isn't a link
    content = re.sub(r"```.*?```", "", content, flags=re.DOTALL)
    for m in LINK_RE.finditer(content):
        target = m.group(1)
        if target.startswith(EXTERNAL):
            continue
        path_part, _, anchor = target.partition("#")
        if not path_part:                      # same-file #anchor
            dest = md
        else:
            dest = (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md.relative_to(repo_root)}: broken link "
                              f"-> {target}")
                continue
        if anchor and dest.suffix == ".md":
            # Compare the fragment *raw*: GitHub matches it against the
            # lowercase heading slug case-sensitively, so normalizing the
            # fragment here would bless miscased anchors that 404 live.
            if anchor not in headings_of(dest):
                errors.append(f"{md.relative_to(repo_root)}: missing anchor "
                              f"-> {target}")
    return errors


def main(argv: list[str]) -> int:
    repo_root = Path(__file__).resolve().parent.parent
    files = [Path(a).resolve() for a in argv] or sorted(
        list(repo_root.glob("*.md")) + list(repo_root.glob("docs/*.md"))
        + list(repo_root.glob("benchmarks/*.md")))
    errors: list[str] = []
    checked = 0
    for md in files:
        if not md.exists():
            errors.append(f"file not found: {md}")
            continue
        checked += 1
        errors.extend(check_file(md, repo_root))
    if errors:
        print(f"LINK CHECK: {len(errors)} broken link(s) across "
              f"{checked} file(s):")
        for e in errors:
            print(f"  FAIL {e}")
        return 1
    print(f"link check OK: {checked} markdown file(s), all relative links "
          f"and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
