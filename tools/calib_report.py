#!/usr/bin/env python
"""Render a calibration payload (``BENCH_calib.json``) as tables.

Stdlib-only CLI over the JSON that ``benchmarks/bench_engine_convergence
--calib-json`` writes (or any ``Observability.snapshot()`` containing
``calibration`` / ``predictor_calibration`` sections):

    python tools/calib_report.py BENCH_calib.json
    python tools/calib_report.py BENCH_calib.json --json

Prints the per-op-class cost-model residual table (fitted scale/offset,
post-fit residual p50/p90, drift state), the worst-drifting op classes,
and the length predictor's calibration curve + ECE/coverage/bias.
``--json`` emits the same derived view as machine-readable JSON on
stdout instead (the "calibration curves as JSON" surface).  CI runs this
as a smoke check over the quick-bench calibration artifact.
"""

from __future__ import annotations

import argparse
import json
import math
import sys


def _sections(doc: dict) -> tuple[dict, dict]:
    """(cost_calibration, predictor_calibration) snapshots out of either
    payload shape: the bench artifact (``cost_calibration`` /
    ``predictor_calibration``) or a bare obs snapshot (``calibration`` /
    ``predictor_calibration``)."""
    cost = doc.get("cost_calibration", doc.get("calibration", {}))
    pred = doc.get("predictor_calibration", {})
    return cost or {}, pred or {}


def derive(doc: dict) -> dict:
    """Machine-readable derived view: per-class residual rows, worst-drift
    ranking, and the predictor curve — what ``--json`` prints."""
    cost, pred = _sections(doc)
    classes = cost.get("classes", {})
    rows = []
    drifts = []
    for op in sorted(classes):
        row = classes[op]
        res = row.get("residual", {})
        drift = row.get("drift", {})
        rows.append({
            "op_class": op,
            "n": row.get("n", 0),
            "scale": row.get("scale", 1.0),
            "offset": row.get("offset", 0.0),
            "residual_p50": res.get("p50"),
            "residual_p90": res.get("p90"),
            "drifting": drift.get("drifting", False),
        })
        ratio = drift.get("drift_ratio")
        if ratio and ratio > 0:
            drifts.append({"op_class": op, "drift_ratio": ratio,
                           "abs_log_drift": abs(math.log(ratio))})
    drifts.sort(key=lambda d: d["abs_log_drift"], reverse=True)
    return {
        "classes": rows,
        "worst_drift": drifts,
        "correction": cost.get("correction", {}),
        "dropped": cost.get("dropped", 0),
        "predictor": {
            "observed": pred.get("observed", 0),
            "abstained": pred.get("abstained", 0),
            "ece": pred.get("ece"),
            "coverage": pred.get("coverage"),
            "bias": pred.get("bias"),
            "curve": pred.get("curve", []),
            "worst_keys": pred.get("worst_keys", []),
        },
    }


def render(view: dict) -> None:
    rows = view["classes"]
    if rows:
        print("cost-model calibration (per op class):")
        print(f"  {'op_class':14s} {'n':>6s} {'scale':>8s} {'offset':>11s} "
              f"{'res_p50':>8s} {'res_p90':>8s} {'drift':>6s}")
        for r in rows:
            p50 = f"{r['residual_p50']:.3f}" if r["residual_p50"] else "-"
            p90 = f"{r['residual_p90']:.3f}" if r["residual_p90"] else "-"
            print(f"  {r['op_class']:14s} {r['n']:6d} {r['scale']:8.3f} "
                  f"{r['offset']:11.6f} {p50:>8s} {p90:>8s} "
                  f"{'DRIFT' if r['drifting'] else 'ok':>6s}")
    else:
        print("cost-model calibration: no samples")
    if view["worst_drift"]:
        print("\nworst drift (|log recent/global scale|, descending):")
        for d in view["worst_drift"]:
            print(f"  {d['op_class']:14s} drift_ratio="
                  f"{d['drift_ratio']:.3f}")
    p = view["predictor"]
    print(f"\nlength predictor: observed={p['observed']} "
          f"abstained={p['abstained']}")
    if p["observed"]:
        print(f"  ece={p['ece']:.4f} coverage={p['coverage']:.3f} "
              f"bias={p['bias']:+.4f}")
        if p["curve"]:
            print(f"  {'pred bin':>16s} {'n':>5s} {'mean_pred':>10s} "
                  f"{'mean_actual':>12s}")
            for b in p["curve"]:
                print(f"  [{b['lo']:6.0f},{b['hi']:6.0f}) {b['n']:5d} "
                      f"{b['mean_predicted']:10.2f} "
                      f"{b['mean_actual']:12.2f}")
        for k in p["worst_keys"]:
            print(f"  worst key {k['key']}: n={k['n']} "
                  f"bias={k['bias']:+.4f} coverage={k['coverage']:.3f}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("payload", help="BENCH_calib.json (or an obs snapshot "
                                    "with a 'calibration' section)")
    ap.add_argument("--json", action="store_true",
                    help="emit the derived view as JSON instead of tables")
    args = ap.parse_args(argv)
    with open(args.payload) as f:
        doc = json.load(f)
    view = derive(doc)
    if not view["classes"] and not view["predictor"]["observed"]:
        print(f"{args.payload}: no calibration sections found",
              file=sys.stderr)
        return 1
    if args.json:
        json.dump(view, sys.stdout, indent=2, sort_keys=True)
        print()
    else:
        render(view)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
