"""Replay one recorded arrival trace through the DES and the real engine.

Thin CLI over :mod:`repro.serving.replay` — the DES↔engine equivalence
harness (docs/ENGINE.md documents the methodology and what each bound
means).  Prints a per-scheduler divergence table and optionally writes the
full JSON report (the artifact CI uploads).

    PYTHONPATH=src python tools/replay_trace.py --quick --json replay.json

Exits 1 when any scheduler violates its documented bound (exact dispatch
equality for FCFS/SJF; rank correlation >= TAU_BOUND for EWSJF).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.serving.replay import replay_ok, run_suite  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=12,
                    help="requests in the burst trace")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="llama2-13b",
                    help="smoke-config architecture (dense attention only)")
    ap.add_argument("--schedulers", default="fcfs,sjf,ewsjf",
                    help="comma-separated scheduler registry names")
    ap.add_argument("--quick", action="store_true",
                    help="small trace for CI (n=8)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full divergence report as JSON")
    args = ap.parse_args()
    n = 8 if args.quick else args.n

    suite = run_suite(n=n, seed=args.seed, arch=args.arch,
                      schedulers=tuple(args.schedulers.split(",")))
    print(f"replay equivalence: arch={suite['arch']} "
          f"n={suite['n_requests']} seed={suite['seed']}")
    print(f"{'scheduler':>10} {'dispatch':>9} {'tau':>6} "
          f"{'ttft_tau':>8} {'bound':>14} {'ok':>4}")
    for r in suite["reports"]:
        bound = "exact" if r["exact_required"] else f"tau>={r['tau_bound']}"
        ok = replay_ok(r)
        print(f"{r['scheduler']:>10} "
              f"{'match' if r['dispatch_match'] else 'diverge':>9} "
              f"{r['dispatch_tau']:>6.3f} {r['ttft_tau']:>8.3f} "
              f"{bound:>14} {'yes' if ok else 'NO':>4}")
    if args.json:
        Path(args.json).write_text(json.dumps(suite, indent=2))
        print(f"wrote {args.json}")
    return 0 if suite["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
