#!/usr/bin/env python
"""Offline summary of a Chrome-trace JSON exported by the obs plane.

Stdlib-only CLI over the Perfetto-loadable trace that
``TraceRecorder.to_chrome_trace`` (and ``benchmarks/bench_cluster_routing
--trace``) writes:

    python tools/trace_summary.py trace_sample.json
    python tools/trace_summary.py trace_sample.json --top 5
    python tools/trace_summary.py trace_sample.json --request 42
    python tools/trace_summary.py trace_sample.json --slot 2

Reports the top-N slowest requests (arrival → finish) with their
wait / prefill / decode stage split, the per-stage aggregate breakdown,
and per-replica engine occupancy from the spans — both per span name and
grouped by stage (the engine's ``chunk`` / ``recompute`` spans are
prefill-stage work, ``attach`` is the radix prefix-KV copy).  ``--slot``
prints one engine slot's lifecycle (every span and instant carrying that
slot), mirroring ``--request``.  CI runs this as a smoke check over the
quick-bench trace artifacts.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

# Span-name -> stage grouping; mirrors repro.obs.trace.SPAN_STAGES (this
# tool stays stdlib-only, so the map is duplicated rather than imported —
# keep the two in sync).  Unknown span names group under "other".
SPAN_STAGES = {
    "prefill": "prefill", "chunk": "prefill", "recompute": "prefill",
    "attach": "attach", "decode": "decode",
}


def load_events(path: str) -> list[dict]:
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents", doc if isinstance(doc, list) else [])
    return [e for e in events if e.get("ph") in ("X", "i")]


def lifecycles(events: list[dict]) -> dict[int, dict[str, float]]:
    """request_id -> {kind: first-seen time (seconds)} for instant events."""
    out: dict[int, dict[str, float]] = defaultdict(dict)
    for e in events:
        if e.get("ph") != "i":
            continue
        rid = e.get("args", {}).get("request_id", e.get("tid"))
        if rid is None:
            continue
        kind = e["name"]
        t = e["ts"] / 1e6
        if kind not in out[rid] or t < out[rid][kind]:
            out[rid][kind] = t
    return dict(out)


def stage_split(ev: dict[str, float]) -> dict[str, float]:
    """wait/prefill/decode/total seconds for one request's event map
    (same boundaries as TraceRecorder.stage_breakdown)."""
    out = {"wait": 0.0, "prefill": 0.0, "decode": 0.0, "total": 0.0}
    arr = ev.get("arrival", ev.get("enqueue"))
    if arr is None:
        return out
    if "dispatch" in ev:
        out["wait"] = max(0.0, ev["dispatch"] - arr)
    if "first_token" in ev and "dispatch" in ev:
        out["prefill"] = max(0.0, ev["first_token"] - ev["dispatch"])
    if "finish" in ev and "first_token" in ev:
        out["decode"] = max(0.0, ev["finish"] - ev["first_token"])
    end = ev.get("finish", max(ev.values()))
    out["total"] = max(0.0, end - arr)
    return out


def engine_occupancy(events: list[dict]) -> dict[int, dict[str, float]]:
    """replica pid -> {span name: total busy seconds} from X-phase spans."""
    out: dict[int, dict[str, float]] = defaultdict(lambda: defaultdict(float))
    for e in events:
        if e.get("ph") == "X":
            out[e.get("pid", 0)][e["name"]] += e.get("dur", 0.0) / 1e6
    return {pid: dict(spans) for pid, spans in out.items()}


def stage_occupancy(events: list[dict]) -> dict[int, dict[str, float]]:
    """replica pid -> {stage: busy seconds}: spans folded through
    SPAN_STAGES so the engine's chunk/recompute/attach names land in the
    same stage taxonomy the DES reports."""
    out: dict[int, dict[str, float]] = defaultdict(lambda: defaultdict(float))
    for e in events:
        if e.get("ph") == "X":
            stage = SPAN_STAGES.get(e["name"], "other")
            out[e.get("pid", 0)][stage] += e.get("dur", 0.0) / 1e6
    return {pid: dict(stages) for pid, stages in out.items()}


def slot_events(events: list[dict], slot: int) -> list[dict]:
    """Every span/instant carrying ``args.slot == slot``, time-ordered —
    one engine slot's lifecycle (park → attach → chunk* → promote →
    preempt/finish cycles)."""
    out = [e for e in events
           if e.get("args", {}).get("slot") == slot]
    out.sort(key=lambda e: e.get("ts", 0.0))
    return out


def summarize(path: str, top: int = 10,
              request: int | None = None,
              slot: int | None = None) -> int:
    events = load_events(path)
    if not events:
        print(f"{path}: no trace events", file=sys.stderr)
        return 1
    lives = lifecycles(events)
    splits = {rid: stage_split(ev) for rid, ev in lives.items()}

    if slot is not None:
        evs = slot_events(events, slot)
        if not evs:
            print(f"slot {slot}: no events in trace window", file=sys.stderr)
            return 1
        print(f"slot {slot}: {len(evs)} events")
        for e in evs:
            t = e.get("ts", 0.0) / 1e6
            dur = e.get("dur", 0.0) / 1e6 if e.get("ph") == "X" else 0.0
            rid = e.get("args", {}).get("request_id", "-")
            tail = f" dur={dur:.4f}s" if dur else ""
            print(f"  t={t:9.4f}s  {e['name']:10s} request={rid}{tail}")
        busy = defaultdict(float)
        for e in evs:
            if e.get("ph") == "X":
                busy[SPAN_STAGES.get(e["name"], "other")] += \
                    e.get("dur", 0.0) / 1e6
        if busy:
            print("  busy: " + " ".join(f"{k}={v:.4f}s" for k, v in
                                        sorted(busy.items())))
        return 0

    if request is not None:
        ev = lives.get(request)
        if ev is None:
            print(f"request {request}: not in trace window", file=sys.stderr)
            return 1
        print(f"request {request}:")
        for kind, t in sorted(ev.items(), key=lambda kv: kv[1]):
            print(f"  t={t:9.4f}s  {kind}")
        br = splits[request]
        print(f"  stages: wait={br['wait']:.4f}s prefill={br['prefill']:.4f}s "
              f"decode={br['decode']:.4f}s total={br['total']:.4f}s")
        return 0

    n = len(splits)
    finished = sum(1 for ev in lives.values() if "finish" in ev)
    print(f"{path}: {len(events)} events, {n} requests in window "
          f"({finished} finished)")

    agg = {"wait": 0.0, "prefill": 0.0, "decode": 0.0, "total": 0.0}
    for br in splits.values():
        for k in agg:
            agg[k] += br[k]
    if agg["total"] > 0:
        print("\nper-stage share of request time (all requests in window):")
        for k in ("wait", "prefill", "decode"):
            print(f"  {k:8s} {agg[k]:9.3f}s  ({agg[k] / agg['total']:5.1%})")

    slowest = sorted(splits.items(), key=lambda kv: kv[1]["total"],
                     reverse=True)[:top]
    print(f"\ntop {len(slowest)} slowest requests (arrival → finish):")
    print(f"  {'request':>8s} {'total':>9s} {'wait':>9s} {'prefill':>9s} "
          f"{'decode':>9s}")
    for rid, br in slowest:
        print(f"  {rid:8d} {br['total']:8.4f}s {br['wait']:8.4f}s "
              f"{br['prefill']:8.4f}s {br['decode']:8.4f}s")

    occ = engine_occupancy(events)
    if occ:
        print("\nper-replica engine busy time (spans):")
        for pid in sorted(occ):
            spans = " ".join(f"{k}={v:.3f}s" for k, v in
                             sorted(occ[pid].items()))
            print(f"  replica {pid}: {spans}")
        st_occ = stage_occupancy(events)
        print("\nper-replica engine busy time (stages):")
        for pid in sorted(st_occ):
            stages = " ".join(f"{k}={v:.3f}s" for k, v in
                              sorted(st_occ[pid].items()))
            print(f"  replica {pid}: {stages}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome-trace JSON (from --trace / "
                                  "dump_chrome_trace)")
    ap.add_argument("--top", type=int, default=10,
                    help="how many slowest requests to list (default 10)")
    ap.add_argument("--request", type=int, default=None,
                    help="print one request's full lifecycle instead")
    ap.add_argument("--slot", type=int, default=None,
                    help="print one engine slot's lifecycle instead")
    args = ap.parse_args(argv)
    return summarize(args.trace, top=args.top, request=args.request,
                     slot=args.slot)


if __name__ == "__main__":
    raise SystemExit(main())
