#!/usr/bin/env python
"""Fleet-conformance de-flake smoke: rerun the randomized fleet harness
under several fixed seeds and fail on any cross-seed divergence.

The fleet tests (tests/test_engine_fleet.py, tests/test_fleet_conformance.py)
read ``FLEET_SEED`` from the environment to reseed their randomized
drivers.  A property that only holds for the default seed is a latent
flake; this tool runs the fast-lane subset under each seed in turn so CI
catches seed-dependent behaviour before it ships.

CLI: ``python tools/check_seeds.py [--seeds 0,1,2] [--fast]``
Exit status: non-zero if any seed's run fails.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

TEST_FILES = ("tests/test_engine_fleet.py",
              "tests/test_fleet_conformance.py")


def run_seed(seed: int, fast: bool) -> tuple[bool, float, str]:
    env = dict(os.environ)
    env["FLEET_SEED"] = str(seed)
    env.setdefault("PYTHONPATH", "src")
    cmd = [sys.executable, "-m", "pytest", *TEST_FILES, "-q"]
    if fast:
        cmd += ["-m", "not slow"]
    t0 = time.time()
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    dt = time.time() - t0
    tail = "\n".join(proc.stdout.strip().splitlines()[-5:])
    return proc.returncode == 0, dt, tail


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated FLEET_SEED values to sweep")
    ap.add_argument("--fast", action="store_true",
                    help="fast lane only (-m 'not slow')")
    args = ap.parse_args(argv)
    seeds = [int(s) for s in args.seeds.split(",") if s.strip() != ""]

    failures = []
    print(f"seed sweep over {seeds} ({'fast lane' if args.fast else 'all'})")
    for seed in seeds:
        ok, dt, tail = run_seed(seed, args.fast)
        status = "ok" if ok else "FAIL"
        print(f"  FLEET_SEED={seed}: {status}  ({dt:.1f}s)")
        if not ok:
            failures.append(seed)
            print("    " + tail.replace("\n", "\n    "))
    if failures:
        print(f"cross-seed divergence: seeds {failures} failed "
              f"while others passed — fleet harness is seed-dependent")
        return 1
    print("all seeds green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
