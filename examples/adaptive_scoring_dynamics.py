"""Paper Figure 2: how context-aware scoring shifts queue priorities as the
meta-optimizer adjusts weights.

    PYTHONPATH=src python examples/adaptive_scoring_dynamics.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import (CostModel, MetaParams, Request, compute_score,
                        make_cost_fn, weights_for_queue)
from repro.core.scoring import QueueProfile


def main() -> None:
    c = make_cost_fn(CostModel())
    queues = {"short": (0, 64.0), "medium": (4, 512.0), "long": (9, 3500.0)}
    thetas = {
        "t0 (urgency-heavy)": MetaParams(a_urg=-0.2, b_urg=2.5, a_fair=0.2,
                                         b_fair=0.1),
        "t1 (balanced)": MetaParams(),
        "t2 (fairness-heavy)": MetaParams(a_urg=-0.8, b_urg=1.0, a_fair=1.5,
                                          b_fair=0.8),
    }
    wait = 20.0
    print(f"{'policy':22s} " + " ".join(f"{q:>10s}" for q in queues))
    for name, meta in thetas.items():
        scores = []
        for qname, (idx, mean_len) in queues.items():
            prof = QueueProfile(index=idx, mean_len=mean_len,
                                weights=weights_for_queue(meta, mean_len))
            req = Request(prompt_len=int(mean_len), arrival_time=0.0)
            scores.append(compute_score(req, prof, now=wait, c_prefill=c))
        total = sum(scores)
        rel = [s / total for s in scores]
        print(f"{name:22s} " + " ".join(f"{r:10.1%}" for r in rel))
    print("\nrelative priority shifts with the meta-policy — the paper's "
          "Fig. 2 dynamic.")


if __name__ == "__main__":
    main()
