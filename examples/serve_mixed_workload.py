"""End-to-end driver (deliverable b): REAL JAX model serving with batched
requests through the continuous-batching engine, EWSJF vs FCFS.

    PYTHONPATH=src python examples/serve_mixed_workload.py [--arch qwen3-4b]

Uses the reduced (smoke) config of the chosen architecture so it runs on
CPU in ~a minute; the same engine serves the full configs on a TPU mesh.
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.core import EWSJFConfig, EWSJFScheduler, FCFSScheduler, Request
from repro.models import init_params
from repro.serving import EngineConfig, ServingEngine


def mixed_requests(n, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        short = rng.random() < 0.75
        ln = int(rng.integers(8, 28)) if short else int(rng.integers(96, 200))
        reqs.append(Request(prompt_len=ln, arrival_time=0.0,
                            max_new_tokens=int(rng.integers(2, 8))))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"arch={cfg.name}  d_model={cfg.d_model}  layers={cfg.n_layers}")

    for name, sched in [("fcfs", FCFSScheduler()),
                        ("ewsjf", EWSJFScheduler(EWSJFConfig(
                            min_history=8, reopt_interval=0.5)))]:
        eng = ServingEngine(cfg, params, sched,
                            EngineConfig(max_slots=4, s_max=256,
                                         kv_pool_tokens=4096,
                                         buckets=(32, 64, 128, 256)))
        fin = eng.run(mixed_requests(args.requests), max_steps=5000)
        st = eng.stats()
        ttft = np.mean([r.ttft for r in fin if r.ttft is not None])
        print(f"{name:6s}: served {st['finished']} reqs, "
              f"padding_waste={st['padding_waste']:.1%}, "
              f"prefill_batches={st['prefill_batches']}, "
              f"mean_ttft={ttft:.2f}s (wall)")


if __name__ == "__main__":
    main()
