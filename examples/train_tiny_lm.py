"""Train a ~tiny LM for a few hundred steps with checkpoint/restart
(deliverable b: training driver).

    PYTHONPATH=src python examples/train_tiny_lm.py
"""

import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.models.transformer import MoECtx
from repro.training import (AdamWConfig, DataConfig, TokenDataset,
                            init_train_state, make_train_step)


def main() -> None:
    cfg = get_smoke_config("llama2-13b")
    steps = 120
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(lr_peak=3e-3, warmup_steps=10, total_steps=steps),
        MoECtx(), remat=True))
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    it = TokenDataset(cfg, DataConfig(global_batch=8, seq_len=64)).batches()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        for step in range(1, steps + 1):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt, m = step_fn(params, opt, batch)
            if step % 20 == 0:
                save_checkpoint(ckpt_dir, step, (params, opt))
                print(f"step {step:4d}  loss={float(m['loss']):.4f}  "
                      f"(checkpointed)")
        # simulate a crash + restart
        (params, opt), restored, _ = restore_checkpoint(ckpt_dir,
                                                        (params, opt))
        print(f"restored from step {restored}; continuing 10 more steps")
        for step in range(restored + 1, restored + 11):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt, m = step_fn(params, opt, batch)
        print(f"final loss={float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
