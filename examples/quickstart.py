"""Quickstart: EWSJF scheduling a mixed workload end-to-end.

    PYTHONPATH=src python examples/quickstart.py

1. Generates the paper's bimodal mixed workload (80% short / 20% long).
2. Lets Refine-and-Prune discover the queue structure.
3. Serves the stream through the discrete-event engine under FCFS, SJF and
   EWSJF; prints throughput / TTFT / starvation.
"""

import copy
import sys

sys.path.insert(0, "src")

from repro.core import (CostModel, EngineParams, EWSJFConfig, EWSJFScheduler,
                        FCFSScheduler, PartitionConfig, ServingSimulator,
                        SJFScheduler, WorkloadSpec, refine_and_prune)
from repro.core.cost_model import LLAMA2_13B_COST


def main() -> None:
    wl = WorkloadSpec(n_requests=1500, arrival_rate=20.0, seed=0)
    requests = wl.generate()

    # --- the paper's strategic core, standalone -------------------------
    lens = [r.prompt_len for r in requests[:512]]
    bounds = refine_and_prune(lens, PartitionConfig(max_queues=16))
    print(f"Refine-and-Prune discovered {len(bounds)} queues:")
    for b in bounds[:6]:
        print(f"   [{b.lo:7.1f}, {b.hi if b.hi != float('inf') else 1e9:7.1f})")
    print("   ...")

    # --- full serving comparison ----------------------------------------
    cost = CostModel(model=LLAMA2_13B_COST, n_chips=4, mfu=0.15, hbm_eff=0.7)
    params = EngineParams(max_num_seqs=256, kv_pool_tokens=131072,
                          bucket_pad=False, ttft_timeout=90.0)
    print(f"\n{'sched':8s} {'tok/s':>8s} {'req/s':>7s} {'ttft(short)':>12s} "
          f"{'long starved':>13s}")
    for name, sched in [
            ("fcfs", FCFSScheduler()),
            ("sjf", SJFScheduler()),
            ("ewsjf", EWSJFScheduler(EWSJFConfig(min_history=64), cost))]:
        r = ServingSimulator(sched, cost, params).run(copy.deepcopy(requests))
        ts = r.ttft_stats()
        la = sum(1 for q in r.aborted if q.prompt_len > 256)
        lf = sum(1 for q in r.finished if q.prompt_len > 256)
        print(f"{name:8s} {r.tok_per_s:8.1f} {r.req_per_s:7.2f} "
              f"{ts['short']['mean']:11.2f}s "
              f"{la / max(la + lf, 1):12.1%}")


if __name__ == "__main__":
    main()
