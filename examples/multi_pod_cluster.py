"""Cluster control-plane demo: EWSJF-aware routing over a replica fleet
with failures and stragglers (scripted fault injection), a disaggregated
prefill/decode pool with KV-handoff accounting, and a *reactive* SLO-burn
autoscaler absorbing a traffic burst with re-admission of shed work.

    PYTHONPATH=src python examples/multi_pod_cluster.py
"""

import copy
import sys

sys.path.insert(0, "src")

from repro.cluster import (AdmissionConfig, AdmissionController,
                           AutoscalerConfig, ClusterSimulator, PolicyStore,
                           PolicyStoreConfig, PrefixDirectory, ReplicaParams,
                           RolePoolConfig, ScenarioEvent, SLOBurnAutoscaler,
                           make_fleet, make_router)
from repro.core import CostModel, EWSJFConfig, EWSJFScheduler, WorkloadSpec
from repro.kvplane import SharedPrefixWorkloadSpec, agentic_mix
from repro.obs import Observability


def scheduler_factory():
    return EWSJFScheduler(EWSJFConfig(min_history=32, reopt_interval=5.0,
                                      trial_interval=10.0))


def print_result(res):
    ttft = res.ttft_stats()
    print(f"  finished {len(res.finished)} | shed {len(res.shed)} | "
          f"dropped {len(res.dropped)} | re-enqueued {res.reenqueued}")
    print(f"  short mean TTFT {ttft['short']['mean']*1e3:7.1f} ms | "
          f"long mean TTFT {ttft['long']['mean']*1e3:7.1f} ms | "
          f"{res.tok_per_s:7.1f} tok/s")
    for s in res.replica_stats:
        print(f"   replica {s['replica_id']} ({s['role']:8s} "
              f"speed={s['speed']:4.2f}) served={s['served']:4d} "
              f"alive={s['alive']} draining={s['draining']} "
              f"kv_occ={s['kv_occupancy']:.2f}")
    if res.handoff_stats["handoffs"]:
        h = res.handoff_stats
        print(f"   KV handoffs: {h['handoffs']} | {h['total_gb']:.1f} GB "
              f"| mean transfer {h['mean_transfer_ms']:.2f} ms")
    if res.health["failures"] or res.health["stragglers"]:
        print(f"   health: failed={res.health['failures']} "
              f"stragglers_drained={res.health['stragglers']}")


def main() -> None:
    cost = CostModel(mfu=0.15, hbm_eff=0.7)
    workload = WorkloadSpec(n_requests=400, arrival_rate=30.0).generate()

    print("== scenario 1: unified fleet with failure / straggler / scale-up")
    fleet = make_fleet(4, cost, scheduler_factory=scheduler_factory,
                       speeds=[1.0, 1.0, 1.0, 0.25])   # replica 3 straggles
    sim = ClusterSimulator(fleet, make_router("ewsjf", cost), cost,
                           admission=AdmissionController(shed_factor=4.0))
    res = sim.run(workload, scenario=[
        ScenarioEvent(time=1.0, action="fail", replica_id=0),
        ScenarioEvent(time=4.0, action="add_replica",
                      scheduler_factory=scheduler_factory, speed=1.2),
    ])
    print("!! replica 0 hard-failed at t=1 (in-flight work re-enqueued)")
    print("++ elastic scale-up at t=4; straggler drained by health monitor")
    print_result(res)

    print("\n== scenario 2: disaggregated 2x prefill + 2x decode pools")
    fleet = make_fleet(4, cost, scheduler_factory=scheduler_factory,
                       roles=["prefill", "prefill", "decode", "decode"])
    sim = ClusterSimulator(fleet, make_router("ewsjf", cost), cost)
    res = sim.run(WorkloadSpec(n_requests=400, arrival_rate=20.0,
                               seed=1).generate())
    print_result(res)

    print("\n== scenario 3: reactive autoscaler rides out a burst "
          "(no scripted scale-up)")
    burst = WorkloadSpec(n_requests=300, arrival_rate=30.0, seed=2).generate()
    tail = WorkloadSpec(n_requests=80, arrival_rate=4.0, seed=3).generate()
    t0 = burst[-1].arrival_time
    for r in tail:
        r.arrival_time += t0
    fleet = make_fleet(1, cost, scheduler_factory=scheduler_factory)
    autoscaler = SLOBurnAutoscaler(
        scheduler_factory=scheduler_factory,
        cfg=AutoscalerConfig(max_replicas=6, cooldown_up=0.5, up_patience=1))
    sim = ClusterSimulator(
        fleet, make_router("ewsjf", cost), cost,
        admission=AdmissionController(config=AdmissionConfig(
            shed_factor=1.5, retry_capacity=64)),
        autoscaler=autoscaler)
    res = sim.run(burst + tail)
    print_result(res)
    print(f"   autoscale: {res.autoscale['scale_ups']} ups, "
          f"{res.autoscale['scale_downs']} downs | "
          f"readmitted {res.readmitted} | "
          f"final burn {{{', '.join(f'{k}={v:.2f}' for k, v in res.autoscale['burn'].items())}}}")
    for t, action, rid, _role in res.autoscale["events"]:
        print(f"   t={t:6.2f}s scale-{action} (replica {rid})")

    print("\n== scenario 4: fleet strategic plane (shared policy store, "
          "warm-started scale-up)")
    store = PolicyStore(PolicyStoreConfig(sync_interval=2.0,
                                          local_adaptation=0.25))
    fleet = make_fleet(3, cost, scheduler_factory=scheduler_factory)
    sim = ClusterSimulator(fleet, make_router("ewsjf", cost), cost,
                           policy_store=store)
    wl = WorkloadSpec(n_requests=400, arrival_rate=24.0, seed=4).generate()
    t_add = wl[len(wl) // 2].arrival_time
    res = sim.run(wl, scenario=[
        ScenarioEvent(time=t_add, action="add_replica",
                      scheduler_factory=scheduler_factory)])
    print_result(res)
    print(f"   policy store: epoch {res.policy['epoch']} | "
          f"{res.policy['n_queues']} global queues | "
          f"{res.policy['n_trials']} pooled trials | "
          f"{res.policy['merges']} merges")
    new = sim.replicas[-1]
    print(f"   replica {new.replica_id} scaled up at t={t_add:.2f}s with a "
          f"warm-started policy (no single-queue relearning); by end of "
          f"run it tracks fleet epoch {new.sched.adopted_epoch} "
          f"({len(new.sched.manager.queues)} queues)")

    print("\n== scenario 5: prefix-reuse KV plane (multi-turn/agentic "
          "traffic, radix caches + fleet prefix directory)")
    spec = SharedPrefixWorkloadSpec(n_sessions=20, turns_per_session=6,
                                    session_rate=3.0, think_time=1.0,
                                    system_prompt_len=128,
                                    user_turn_range=(64, 192),
                                    mean_output_tokens=96, seed=5)
    bg = WorkloadSpec(n_requests=60, arrival_rate=6.0, seed=6).generate()
    wl = agentic_mix(spec, bg)
    for label, cache, directory in (("prefix-blind EWSJF", False, None),
                                    ("prefix-aware KV plane", True,
                                     PrefixDirectory())):
        fleet = make_fleet(4, cost, scheduler_factory=scheduler_factory,
                           params=ReplicaParams(enable_prefix_cache=cache))
        sim = ClusterSimulator(fleet, make_router("ewsjf", cost), cost,
                               prefix_directory=directory)
        res = sim.run(copy.deepcopy(wl))
        st = res.ttft_stats()
        extra = ""
        if cache:
            d = res.prefix["directory"]
            extra = (f" | saved {res.prefix['saved_tokens']} prefill tokens"
                     f" | directory epoch {d['epoch']}, {d['entries']} hot "
                     f"prefixes")
        print(f"   {label:24s} short TTFT {st['short']['mean'] * 1e3:7.1f} ms"
              f" | {res.tok_per_s:6.1f} tok/s{extra}")

    print("\n== scenario 6: role-aware autoscaling on a disaggregated "
          "fleet (prefill burst)")
    burst = WorkloadSpec(n_requests=240, arrival_rate=40.0,
                         short_range=(32, 256), seed=7).generate()
    tail = WorkloadSpec(n_requests=80, arrival_rate=5.0, seed=8).generate()
    t0 = burst[-1].arrival_time
    for r in tail:
        r.arrival_time += t0
    pools = (RolePoolConfig(role="prefill", max_replicas=5, up_patience=1,
                            cooldown_up=0.75),
             RolePoolConfig(role="decode", max_replicas=5, up_patience=1,
                            cooldown_up=0.75))
    autoscaler = SLOBurnAutoscaler(
        scheduler_factory=scheduler_factory,
        cfg=AutoscalerConfig(pools=pools, fleet_max_replicas=8))
    fleet = make_fleet(2, cost, scheduler_factory=scheduler_factory,
                       roles=["prefill", "decode"])
    sim = ClusterSimulator(fleet, make_router("ewsjf", cost), cost,
                           autoscaler=autoscaler)
    res = sim.run(burst + tail)
    print_result(res)
    by_role = res.autoscale["by_role"]
    print(f"   role-aware autoscale: "
          + ", ".join(f"{role}: +{v['ups']}/-{v['downs']}"
                      for role, v in sorted(by_role.items()))
          + f" | decode burn {res.autoscale['decode_burn']:.2f} "
          f"(prefill-side burst ⇒ only the prefill pool should grow)")
    print(f"   replica-seconds consumed: {res.replica_seconds:.1f}")
    for t, action, rid, role in res.autoscale["events"]:
        print(f"   t={t:6.2f}s scale-{action} ({role} replica {rid})")

    print("\n== scenario 7: observability plane — tracing a failure + "
          "straggler run, flight-recorder post-mortem")
    obs = Observability.enabled()
    fleet = make_fleet(4, cost, scheduler_factory=scheduler_factory,
                       speeds=[1.0, 1.0, 1.0, 0.25])   # replica 3 straggles
    sim = ClusterSimulator(fleet, make_router("ewsjf", cost), cost,
                           admission=AdmissionController(shed_factor=4.0),
                           obs=obs)
    res = sim.run(WorkloadSpec(n_requests=300, arrival_rate=30.0,
                               seed=9).generate(), scenario=[
        ScenarioEvent(time=1.5, action="fail", replica_id=0)])
    print_result(res)

    # Per-SLO-class latency percentiles straight from the shared registry.
    for cls, view in sorted(obs.slo_report().items()):
        if cls.startswith("_") or "ttft" not in view:
            continue
        t = view["ttft"]
        print(f"   {cls:12s} ttft p50={t['p50']*1e3:6.1f} ms "
              f"p95={t['p95']*1e3:6.1f} ms p99={t['p99']*1e3:6.1f} ms "
              f"(n={t['n']})")

    # The failure froze the tracer ring into a flight dump; post-mortem the
    # worst-hit finished request (longest queue wait) from the recorder.
    stats = obs.trace.stats()
    dumps = ", ".join(f"{reason} @ t={t:.1f}s ({n_ev} events)"
                      for t, reason, n_ev in stats["dumps"])
    print(f"   tracer: {stats['events_emitted']} events emitted | "
          f"flight dump frozen on {dumps}")
    worst = max(res.finished,
                key=lambda r: (r.first_token_time or r.arrival_time)
                - r.arrival_time)
    print(f"   post-mortem of the worst-hit request "
          f"(TTFT {((worst.first_token_time or 0) - worst.arrival_time)*1e3:.1f} ms):")
    for line in obs.trace.postmortem(worst.request_id).splitlines():
        print(f"     {line}")

    # Write the Perfetto-loadable trace next to the repo for inspection.
    path = "multi_pod_trace.json"
    obs.trace.dump_chrome_trace(path)
    print(f"   full trace written to {path} — open at https://ui.perfetto.dev"
          f" (summarize offline: python tools/trace_summary.py {path})")


if __name__ == "__main__":
    main()
