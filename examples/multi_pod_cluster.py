"""Cluster data plane demo: EWSJF-aware routing over a replica fleet with
failures, stragglers, elastic scale-up — then a disaggregated
prefill/decode pool with KV-handoff accounting.

    PYTHONPATH=src python examples/multi_pod_cluster.py
"""

import sys

sys.path.insert(0, "src")

from repro.cluster import (AdmissionController, ClusterSimulator,
                           ScenarioEvent, make_fleet, make_router)
from repro.core import CostModel, EWSJFConfig, EWSJFScheduler, WorkloadSpec


def scheduler_factory():
    return EWSJFScheduler(EWSJFConfig(min_history=32, reopt_interval=5.0,
                                      trial_interval=10.0))


def print_result(res):
    ttft = res.ttft_stats()
    print(f"  finished {len(res.finished)} | shed {len(res.shed)} | "
          f"dropped {len(res.dropped)} | re-enqueued {res.reenqueued}")
    print(f"  short mean TTFT {ttft['short']['mean']*1e3:7.1f} ms | "
          f"long mean TTFT {ttft['long']['mean']*1e3:7.1f} ms | "
          f"{res.tok_per_s:7.1f} tok/s")
    for s in res.replica_stats:
        print(f"   replica {s['replica_id']} ({s['role']:8s} "
              f"speed={s['speed']:4.2f}) served={s['served']:4d} "
              f"alive={s['alive']} draining={s['draining']} "
              f"kv_occ={s['kv_occupancy']:.2f}")
    if res.handoff_stats["handoffs"]:
        h = res.handoff_stats
        print(f"   KV handoffs: {h['handoffs']} | {h['total_gb']:.1f} GB "
              f"| mean transfer {h['mean_transfer_ms']:.2f} ms")
    if res.health["failures"] or res.health["stragglers"]:
        print(f"   health: failed={res.health['failures']} "
              f"stragglers_drained={res.health['stragglers']}")


def main() -> None:
    cost = CostModel(mfu=0.15, hbm_eff=0.7)
    workload = WorkloadSpec(n_requests=400, arrival_rate=30.0).generate()

    print("== scenario 1: unified fleet with failure / straggler / scale-up")
    fleet = make_fleet(4, cost, scheduler_factory=scheduler_factory,
                       speeds=[1.0, 1.0, 1.0, 0.25])   # replica 3 straggles
    sim = ClusterSimulator(fleet, make_router("ewsjf", cost), cost,
                           admission=AdmissionController(shed_factor=4.0))
    res = sim.run(workload, scenario=[
        ScenarioEvent(time=1.0, action="fail", replica_id=0),
        ScenarioEvent(time=4.0, action="add_replica",
                      scheduler_factory=scheduler_factory, speed=1.2),
    ])
    print("!! replica 0 hard-failed at t=1 (in-flight work re-enqueued)")
    print("++ elastic scale-up at t=4; straggler drained by health monitor")
    print_result(res)

    print("\n== scenario 2: disaggregated 2x prefill + 2x decode pools")
    fleet = make_fleet(4, cost, scheduler_factory=scheduler_factory,
                       roles=["prefill", "prefill", "decode", "decode"])
    sim = ClusterSimulator(fleet, make_router("ewsjf", cost), cost)
    res = sim.run(WorkloadSpec(n_requests=400, arrival_rate=20.0,
                               seed=1).generate())
    print_result(res)


if __name__ == "__main__":
    main()
