"""Multi-pod serving with failures, stragglers and elastic scaling:
EWSJF as the global admission layer (DESIGN.md SS3, beyond-paper scope).

    PYTHONPATH=src python examples/multi_pod_cluster.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import CostModel, EWSJFConfig, EWSJFScheduler, Request
from repro.distributed import ClusterConfig, ClusterController


def main() -> None:
    sched = EWSJFScheduler(EWSJFConfig(min_history=16))
    ctl = ClusterController(sched, CostModel(),
                            ClusterConfig(n_pods=4, max_inflight_per_pod=32))
    rng = np.random.default_rng(0)
    for _ in range(200):
        ctl.sched.submit(Request(prompt_len=int(rng.integers(32, 4096)),
                                 max_new_tokens=32), now=0.0)

    ctl.pods[3].speed = 0.1                     # pod 3 is a straggler
    for i in range(120):
        ctl.route_step()
        if i == 10:
            print("!! pod 0 hard-fails (in-flight work re-enqueued)")
            ctl.remove_pod(0, graceful=False)
        if i == 30:
            pid = ctl.add_pod(speed=1.2)
            print(f"++ elastic scale-up: pod {pid} joins")
        ctl.advance(2.0)
        drained = ctl.check_health()
        for p in drained:
            print(f"~~ pod {p} drained (straggler/timeout)")

    print(f"\nserved {len(ctl.finished)}/200 requests; "
          f"re-enqueued after failure: {ctl.reenqueued}")
    for pid, p in sorted(ctl.pods.items()):
        print(f"   pod {pid}: served={p.served:4d} alive={p.alive} "
              f"speed={p.speed}")


if __name__ == "__main__":
    main()
